//! Fleet router tier: one front address, N backend serve processes.
//!
//! A [`Router`] speaks the exact `/v1/models/{name}/…` API of
//! [`crate::serve::server`], but instead of owning engines it
//! **consistent-hashes model names across backends** and proxies each
//! request to the backend that owns the model. Backends are plain
//! `mlsvm serve` processes (spawned children of `mlsvm route --spawn N`,
//! or any addresses handed to `--backends`); they need no router
//! awareness.
//!
//! * **Placement** is a consistent-hash [`Ring`]: FNV-1a 64 over
//!   [`VNODES`] virtual nodes per backend, keyed by the **stable backend
//!   index** (`backend-{i}#{r}`), *not* by address. A backend that dies
//!   and respawns on a new ephemeral port keeps its ring position, so
//!   model placement survives restarts — the property the conformance
//!   suite pins.
//! * **Health**: a background thread probes every backend's `/healthz`
//!   each interval (plus one synchronous round at startup, and passive
//!   marking on connect/IO failure). Unhealthy backends are skipped by
//!   the proxy until a probe brings them back.
//! * **Failover & retries**: a request whose owner is down (or answers
//!   `503`) walks the ring to the next distinct backend under a bounded
//!   budget ([`RouterConfig::retry_budget`] extra attempts), sleeping a
//!   **deterministic exponential backoff with bounded jitter** between
//!   attempts ([`failover_backoff`]: base doubles per attempt, jitter is
//!   FNV-1a over the request key so identical requests back off
//!   identically while different models spread out; total slept time is
//!   reported as `backoff_ms` in `/stats`). Retries only happen
//!   **before any response byte reaches the client** — a mid-relay
//!   failure closes the connection instead of corrupting it. Exhausting
//!   the budget answers a `503` with `Retry-After`, never a hang: every
//!   backend read is bounded by [`RouterConfig::proxy_timeout`].
//! * **Pooling**: completed keep-alive backend exchanges park their
//!   connection in a small per-backend pool, so steady-state proxying
//!   pays no connect cost.
//! * **Streaming**: response bodies relay in bounded copies
//!   ([`COPY_BUF`] bytes at a time) for both `Content-Length` and
//!   chunked framing — the router never materializes a whole
//!   predict-batch answer.
//! * **Fleet routes** fan out: `GET /v1/models` aggregates every
//!   backend's listing (the `models` array is the union of names),
//!   `GET /healthz` probes the fleet, `GET /stats` reports router
//!   counters per backend. Legacy unscoped routes (`/predict`,
//!   `/reload`, …) answer `400` — the router has no default model.
//! * **Auth**: when [`RouterConfig::auth_token`] is set, mutating
//!   endpoints (reload/evict/promote/rollback) require `Authorization:
//!   Bearer` at the router, and the token is forwarded on every proxied
//!   request so token-guarded backends accept it.
//! * **Live backend reconfiguration**: [`Router::update_backends`]
//!   replaces the backend set in place (the `mlsvm route
//!   --backends-file` SIGHUP path). Slots are matched by index:
//!   unchanged addresses keep their health, pool, counters and ring
//!   position; changed ones repoint (unhealthy until a probe proves the
//!   new address); removed slots stop receiving traffic and drop their
//!   pooled connections; added slots enter rotation only after a health
//!   pass marks them up.
//! * **Drain** mirrors the backend server: [`Router::begin_drain`] flips
//!   `/healthz`, refuses new connections, and lets in-flight proxied
//!   pipelines finish before closing cleanly (FIN, never RST);
//!   [`Router::drain`] waits for quiescence.

use crate::error::{Error, Result};
use crate::serve::server::{
    append_response_extra, bearer_auth_failure, error_json, http_request_with_auth, json_escape,
    read_request, refuse_connection, write_response, ConnReader, HttpRequest, Response, JSON,
    RETRY_AFTER,
};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Virtual nodes per backend on the hash ring. More vnodes smooth the
/// key distribution; 64 keeps placement lookup cheap while spreading
/// models to within a few percent of even.
pub const VNODES: usize = 64;

/// Response bodies relay to the client in copies of at most this many
/// bytes — the router's whole-response memory bound.
pub const COPY_BUF: usize = 16 * 1024;

/// Most concurrent client connections the router handles; the excess is
/// refused with a 503 (same shedding as the backend server).
const MAX_CONNS: usize = 256;

/// How long a kept-alive client connection may idle between requests.
const KEEPALIVE_IDLE: Duration = Duration::from_secs(10);

/// Requests served on one client connection before the router closes it.
const MAX_REQUESTS_PER_CONN: usize = 10_000;

/// Backend connect timeout (distinct from the read-side proxy timeout:
/// a dead host must fail fast so the retry budget buys failover, not
/// waiting).
const CONNECT_TIMEOUT: Duration = Duration::from_secs(2);

/// Kept backend connections per backend.
const POOL_CAP: usize = 8;

/// Most same-model pipelined requests multiplexed onto one backend
/// connection in a single burst (in-flight depth of the proxied
/// pipeline).
const MUX_DEPTH_CAP: usize = 16;

/// Largest backend `503` body absorbed for retry bookkeeping; bigger
/// (never expected) drops the connection instead.
const DISCARD_CAP: usize = 64 * 1024;

/// Base delay of the exponential failover backoff (first retry).
const BACKOFF_BASE: Duration = Duration::from_millis(5);

/// Cap on any single failover backoff (step + jitter never exceeds it).
const BACKOFF_CAP: Duration = Duration::from_millis(100);

/// The deterministic backoff slept before failover attempt `attempt`
/// (1-based): [`BACKOFF_BASE`] doubled per attempt, plus a bounded
/// jitter (at most 50% of the step) derived from FNV-1a over the
/// request key and attempt number — the same request backs off
/// identically every time (testable, reproducible), while retries for
/// different models spread off the same instant. Clamped to
/// [`BACKOFF_CAP`].
pub fn failover_backoff(key: &str, attempt: usize) -> Duration {
    let base = BACKOFF_BASE.as_millis() as u64;
    let step = base << attempt.saturating_sub(1).min(4);
    let jitter = fnv1a(format!("{key}#retry{attempt}").as_bytes()) % (step / 2 + 1);
    Duration::from_millis((step + jitter).min(BACKOFF_CAP.as_millis() as u64))
}

/// FNV-1a 64-bit hash — the ring's stable, dependency-free hash.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Consistent-hash ring over `n` backend slots.
///
/// Ring points are hashes of `backend-{index}#{replica}` — the **index**
/// is the identity, so two routers over the same backend count place
/// every model identically, regardless of addresses or construction
/// order, and a respawned backend (same index, new port) keeps its keys.
pub struct Ring {
    /// `(point, backend_index)`, sorted by point.
    points: Vec<(u64, usize)>,
    n: usize,
}

impl Ring {
    /// Ring over backend indices `0..n`.
    pub fn new(n: usize) -> Ring {
        let mut points = Vec::with_capacity(n * VNODES);
        for i in 0..n {
            for r in 0..VNODES {
                points.push((fnv1a(format!("backend-{i}#{r}").as_bytes()), i));
            }
        }
        points.sort_unstable();
        Ring { points, n }
    }

    /// Number of backend slots.
    pub fn backends(&self) -> usize {
        self.n
    }

    /// The backend that owns `key` (first point at or after the key's
    /// hash, wrapping). Requires a non-empty ring.
    pub fn primary(&self, key: &str) -> usize {
        self.order(key)[0]
    }

    /// Every distinct backend in ring-walk order starting at `key`'s
    /// point: `order[0]` is the owner, the rest is the failover order.
    pub fn order(&self, key: &str) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.n);
        if self.points.is_empty() {
            return out;
        }
        let h = fnv1a(key.as_bytes());
        let start = self.points.partition_point(|&(p, _)| p < h);
        for k in 0..self.points.len() {
            let (_, b) = self.points[(start + k) % self.points.len()];
            if !out.contains(&b) {
                out.push(b);
                if out.len() == self.n {
                    break;
                }
            }
        }
        out
    }
}

/// One backend slot: a (mutable) address, a health flag, a small
/// keep-alive connection pool, and counters.
struct Backend {
    addr: Mutex<String>,
    /// Probed by the health thread and passively cleared on proxy
    /// failure; unhealthy backends are skipped by candidate selection.
    healthy: AtomicBool,
    pool: Mutex<Vec<TcpStream>>,
    proxied: AtomicU64,
    errors: AtomicU64,
}

impl Backend {
    fn new(addr: String) -> Backend {
        Backend {
            addr: Mutex::new(addr),
            healthy: AtomicBool::new(false),
            pool: Mutex::new(Vec::new()),
            proxied: AtomicU64::new(0),
            errors: AtomicU64::new(0),
        }
    }

    fn addr(&self) -> String {
        self.addr.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    fn set_addr(&self, addr: String) {
        *self.addr.lock().unwrap_or_else(|e| e.into_inner()) = addr;
        self.clear_pool();
        // Unproven until the next health round (or a successful proxy).
        self.healthy.store(false, Ordering::Relaxed);
    }

    fn take_conn(&self) -> Option<TcpStream> {
        self.pool.lock().unwrap_or_else(|e| e.into_inner()).pop()
    }

    fn put_conn(&self, stream: TcpStream) {
        let mut pool = self.pool.lock().unwrap_or_else(|e| e.into_inner());
        if pool.len() < POOL_CAP {
            pool.push(stream);
        }
    }

    fn clear_pool(&self) {
        self.pool.lock().unwrap_or_else(|e| e.into_inner()).clear();
    }

    fn mark_down(&self) {
        self.healthy.store(false, Ordering::Relaxed);
        self.errors.fetch_add(1, Ordering::Relaxed);
        self.clear_pool();
    }
}

/// Router configuration.
pub struct RouterConfig {
    /// Backend addresses (`host:port`), one per ring slot, in slot order.
    pub backends: Vec<String>,
    /// Bearer token: checked on mutating routes at the router and
    /// forwarded on every proxied request.
    pub auth_token: Option<String>,
    /// Extra proxy attempts after the first (ring-walk failover budget).
    pub retry_budget: usize,
    /// Bound on every backend read during a proxy exchange — a stalled
    /// backend costs this much, then fails over; it can never hang the
    /// router.
    pub proxy_timeout: Duration,
    /// Background health-probe cadence.
    pub health_interval: Duration,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            backends: Vec::new(),
            auth_token: None,
            retry_budget: 2,
            proxy_timeout: Duration::from_secs(10),
            health_interval: Duration::from_millis(500),
        }
    }
}

/// The ring and its backend slots — one coherent unit, swapped together
/// when the backend set is reconfigured ([`Router::update_backends`]).
struct Placement {
    ring: Ring,
    backends: Vec<Arc<Backend>>,
}

/// Shared router state (accept loop, connection handlers, health thread).
struct RouterState {
    placement: RwLock<Placement>,
    auth_token: Option<String>,
    retry_budget: usize,
    proxy_timeout: Duration,
    health_interval: Duration,
    draining: AtomicBool,
    shutdown: AtomicBool,
    proxied: AtomicU64,
    retries: AtomicU64,
    /// Total milliseconds slept in failover backoffs (reported in
    /// `/stats`; zero on an unfaulted fleet).
    backoff_ms: AtomicU64,
    fanouts: AtomicU64,
    /// Multiplexed proxy bursts completed (≥2 same-model pipelined
    /// requests carried in flight on one backend connection).
    mux_batches: AtomicU64,
    /// Requests relayed through multiplexed bursts (depth =
    /// `mux_requests / mux_batches`).
    mux_requests: AtomicU64,
}

impl RouterState {
    /// Snapshot the backend slots (cheap Arc clones). Handlers work off
    /// the snapshot so a concurrent reconfiguration never invalidates
    /// their indices mid-request.
    fn backends(&self) -> Vec<Arc<Backend>> {
        self.placement
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .backends
            .clone()
    }
}

/// What one [`Router::update_backends`] call changed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BackendsUpdate {
    /// New slots appended (unhealthy until a health pass).
    pub added: usize,
    /// Trailing slots removed (traffic to them stops immediately).
    pub removed: usize,
    /// Existing slots whose address changed (unhealthy until probed).
    pub repointed: usize,
}

impl BackendsUpdate {
    /// Whether the call changed anything at all.
    pub fn changed(&self) -> bool {
        *self != BackendsUpdate::default()
    }
}

/// A running fleet router (shuts down on drop).
pub struct Router {
    addr: SocketAddr,
    state: Arc<RouterState>,
    active: Arc<AtomicUsize>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    health_thread: Option<std::thread::JoinHandle<()>>,
}

impl Router {
    /// Bind `bind_addr` and start routing across `cfg.backends`. Runs
    /// one synchronous health round before accepting (so the first
    /// request already knows who is up) and then probes in the
    /// background every `cfg.health_interval`.
    pub fn start(bind_addr: &str, cfg: RouterConfig) -> Result<Router> {
        if cfg.backends.is_empty() {
            return Err(Error::Serve("router needs at least one backend".into()));
        }
        let state = Arc::new(RouterState {
            placement: RwLock::new(Placement {
                ring: Ring::new(cfg.backends.len()),
                backends: cfg
                    .backends
                    .into_iter()
                    .map(|a| Arc::new(Backend::new(a)))
                    .collect(),
            }),
            auth_token: cfg.auth_token,
            retry_budget: cfg.retry_budget,
            proxy_timeout: cfg.proxy_timeout,
            health_interval: cfg.health_interval,
            draining: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
            proxied: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            backoff_ms: AtomicU64::new(0),
            fanouts: AtomicU64::new(0),
            mux_batches: AtomicU64::new(0),
            mux_requests: AtomicU64::new(0),
        });
        check_round(&state);
        let listener = TcpListener::bind(bind_addr)
            .map_err(|e| Error::Serve(format!("bind {bind_addr}: {e}")))?;
        let addr = listener.local_addr()?;
        let active = Arc::new(AtomicUsize::new(0));
        let active_in_loop = Arc::clone(&active);
        let st = Arc::clone(&state);
        let accept_thread = std::thread::Builder::new()
            .name("route-accept".into())
            .spawn(move || {
                let active = active_in_loop;
                for conn in listener.incoming() {
                    if st.shutdown.load(Ordering::Relaxed) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    if st.draining.load(Ordering::SeqCst) {
                        refuse_connection(&stream, "router is draining");
                        continue;
                    }
                    if active.load(Ordering::Relaxed) >= MAX_CONNS {
                        refuse_connection(&stream, "router at connection capacity");
                        continue;
                    }
                    active.fetch_add(1, Ordering::Relaxed);
                    struct Permit(Arc<AtomicUsize>);
                    impl Drop for Permit {
                        fn drop(&mut self) {
                            self.0.fetch_sub(1, Ordering::Relaxed);
                        }
                    }
                    let permit = Permit(Arc::clone(&active));
                    let st = Arc::clone(&st);
                    let _ = std::thread::Builder::new()
                        .name("route-conn".into())
                        .spawn(move || {
                            let _permit = permit;
                            handle_router_connection(stream, &st);
                        });
                }
            })
            .map_err(|e| Error::Serve(format!("spawning router accept loop: {e}")))?;
        let st = Arc::clone(&state);
        let health_thread = std::thread::Builder::new()
            .name("route-health".into())
            .spawn(move || {
                while !st.shutdown.load(Ordering::Relaxed) {
                    // Sleep in short steps so shutdown is prompt even
                    // with a long probe interval.
                    let until = Instant::now() + st.health_interval;
                    while Instant::now() < until {
                        if st.shutdown.load(Ordering::Relaxed) {
                            return;
                        }
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    check_round(&st);
                }
            })
            .map_err(|e| Error::Serve(format!("spawning health thread: {e}")))?;
        Ok(Router {
            addr,
            state,
            active,
            accept_thread: Some(accept_thread),
            health_thread: Some(health_thread),
        })
    }

    /// The bound front address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Client connections currently being handled.
    pub fn active_connections(&self) -> usize {
        self.active.load(Ordering::Relaxed)
    }

    /// The ring slot that owns `model` (placement introspection).
    pub fn place(&self, model: &str) -> usize {
        self.state
            .placement
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .ring
            .primary(model)
    }

    /// Current backend addresses, in slot order.
    pub fn backend_addrs(&self) -> Vec<String> {
        self.state.backends().iter().map(|b| b.addr()).collect()
    }

    /// Whether slot `index`'s backend passed its last health probe.
    pub fn backend_healthy(&self, index: usize) -> bool {
        self.state.backends()[index].healthy.load(Ordering::Relaxed)
    }

    /// Repoint slot `index` at a new address (a respawned backend on a
    /// fresh port keeps its ring position). The slot is unhealthy until
    /// the next probe proves the new address.
    pub fn set_backend_addr(&self, index: usize, addr: impl Into<String>) {
        self.state.backends()[index].set_addr(addr.into());
    }

    /// Replace the backend set in place (the `--backends-file` SIGHUP
    /// path). Slots match by index: an unchanged address keeps its
    /// backend — health, pooled connections, counters and ring position
    /// intact — so a file re-read that changed nothing is free. A
    /// changed address repoints the slot, unhealthy until the next
    /// health pass proves it. Trailing slots beyond the new list are
    /// removed: the router stops routing to them at once and drops
    /// their pooled connections (in-flight exchanges finish off the
    /// snapshot they hold — removal never corrupts a response).
    /// Appended addresses become new slots that start unhealthy and
    /// enter rotation only after a health pass marks them up. The ring
    /// is rebuilt only when the slot count changes (consistent hashing
    /// keeps most placements). Errors on an empty list, leaving the
    /// running set untouched.
    pub fn update_backends(&self, addrs: &[String]) -> Result<BackendsUpdate> {
        if addrs.is_empty() {
            return Err(Error::Serve("router needs at least one backend".into()));
        }
        let mut g = self
            .state
            .placement
            .write()
            .unwrap_or_else(|e| e.into_inner());
        let mut update = BackendsUpdate::default();
        let old_n = g.backends.len();
        for (i, addr) in addrs.iter().enumerate() {
            if i < old_n {
                if g.backends[i].addr() != *addr {
                    g.backends[i].set_addr(addr.clone());
                    update.repointed += 1;
                }
            } else {
                g.backends.push(Arc::new(Backend::new(addr.clone())));
                update.added += 1;
            }
        }
        if addrs.len() < old_n {
            update.removed = old_n - addrs.len();
            g.backends.truncate(addrs.len());
        }
        if g.backends.len() != old_n {
            g.ring = Ring::new(g.backends.len());
        }
        Ok(update)
    }

    /// Run one synchronous health round now; returns how many backends
    /// are up.
    pub fn check_health_now(&self) -> usize {
        check_round(&self.state)
    }

    /// Start a graceful drain: `/healthz` flips to `draining`, new
    /// connections are refused, existing connections close once their
    /// in-flight pipeline is answered. Irreversible by design.
    pub fn begin_drain(&self) {
        self.state.draining.store(true, Ordering::SeqCst);
    }

    /// Whether a drain has begun.
    pub fn draining(&self) -> bool {
        self.state.draining.load(Ordering::SeqCst)
    }

    /// Wait (up to `deadline`) for every in-flight client connection to
    /// finish. Call [`Router::begin_drain`] first.
    pub fn drain(&self, deadline: Duration) -> bool {
        let until = Instant::now() + deadline;
        loop {
            if self.active.load(Ordering::Relaxed) == 0 {
                return true;
            }
            if Instant::now() >= until {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// Stop accepting and join the router threads.
    pub fn shutdown(&mut self) {
        if self.state.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_secs(1));
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        if let Some(h) = self.health_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One health round: probe every backend's `/healthz`, update the flags,
/// drop pools of backends that went down. Returns the healthy count.
fn check_round(state: &RouterState) -> usize {
    let timeout = state.proxy_timeout.min(Duration::from_secs(1));
    let mut up = 0usize;
    for b in &state.backends() {
        let ok = probe_health(&b.addr(), timeout);
        if ok {
            up += 1;
        } else {
            b.clear_pool();
        }
        b.healthy.store(ok, Ordering::Relaxed);
    }
    up
}

/// `GET /healthz` against one backend under a tight timeout; healthy
/// means a 200 status line (a draining backend answers 503 and is
/// treated as down — it must stop receiving traffic).
fn probe_health(addr: &str, timeout: Duration) -> bool {
    let Ok(sa) = addr.parse::<SocketAddr>() else {
        return false;
    };
    let Ok(stream) = TcpStream::connect_timeout(&sa, timeout) else {
        return false;
    };
    stream.set_read_timeout(Some(timeout)).ok();
    stream.set_nodelay(true).ok();
    {
        let mut w = &stream;
        let req = "GET /healthz HTTP/1.1\r\nHost: router\r\nContent-Length: 0\r\nConnection: close\r\n\r\n";
        if w.write_all(req.as_bytes()).and_then(|_| w.flush()).is_err() {
            return false;
        }
    }
    let mut buf = [0u8; 64];
    let mut r = &stream;
    match Read::read(&mut r, &mut buf) {
        Ok(n) if n > 0 => String::from_utf8_lossy(&buf[..n]).contains(" 200 "),
        _ => false,
    }
}

// ---------------------------------------------------------------------------
// Client connection handling
// ---------------------------------------------------------------------------

/// Where one request goes.
enum Target {
    /// Model-scoped: proxy to the ring owner (with failover).
    Model(String),
    /// Fan-out listing (`GET /v1/models`).
    FleetModels,
    /// Fan-out health (`GET /healthz`).
    FleetHealth,
    /// Router counters (`GET /stats`).
    FleetStats,
    /// A legacy unscoped route the router cannot serve (no default
    /// model).
    Bad(&'static str),
    NotFound,
}

fn classify(req: &HttpRequest) -> Target {
    let p = req.path.as_str();
    if req.method == "GET" {
        if p == "/healthz" {
            return Target::FleetHealth;
        }
        if p == "/stats" {
            return Target::FleetStats;
        }
        if p == "/v1/models" || p == "/v1/models/" {
            return Target::FleetModels;
        }
    }
    if let Some(rest) = p.strip_prefix("/v1/models/") {
        let name = rest.split('/').next().unwrap_or("");
        if !name.is_empty() {
            return Target::Model(name.to_string());
        }
        return Target::NotFound;
    }
    if matches!(
        p,
        "/predict" | "/predict-batch" | "/reload" | "/models" | "/stats"
    ) {
        return Target::Bad(
            "the router has no default model; use the routed /v1/models/{name}/... endpoints",
        );
    }
    Target::NotFound
}

/// Whether the request mutates serving state (bearer-guarded when the
/// router has a token).
fn is_mutation(req: &HttpRequest) -> bool {
    if req.method != "POST" {
        return false;
    }
    match req.path.strip_prefix("/v1/models/") {
        Some(rest) => matches!(
            rest.split_once('/'),
            Some((_, "reload")) | Some((_, "evict")) | Some((_, "promote")) | Some((_, "rollback"))
        ),
        None => false,
    }
}

fn handle_router_connection(stream: TcpStream, state: &RouterState) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let _ = stream.set_nodelay(true);
    let mut conn = ConnReader::new(&stream);
    let mut served = 0usize;
    let mut dirty_close = false;
    // A parsed-ahead request that did not join the previous multiplexed
    // burst (different model, mutation, fleet route); served next.
    let mut carry: Option<HttpRequest> = None;
    loop {
        if served == 1 {
            let _ = stream.set_read_timeout(Some(KEEPALIVE_IDLE));
        }
        if carry.is_none() && !conn.has_buffered() && state.draining.load(Ordering::SeqCst) {
            // Everything received so far is answered; close instead of
            // idling on keep-alive. Requests already buffered (an
            // in-flight pipeline) are still served below — a drain
            // finishes work, it never drops it.
            dirty_close = true;
            break;
        }
        let req = match carry.take() {
            Some(r) => r,
            None => match read_request(&mut conn) {
                Ok(req) => req,
                Err(msg) => {
                    if msg != "empty request" {
                        write_response(&stream, "400 Bad Request", JSON, &error_json(msg), false);
                        dirty_close = true;
                    }
                    break;
                }
            },
        };
        served += 1;
        // During a drain, requests already pipelined behind this one are
        // still served; the connection closes with the last buffered one.
        let draining = state.draining.load(Ordering::SeqCst);
        let keep = req.keep_alive
            && served < MAX_REQUESTS_PER_CONN
            && (!draining || conn.has_buffered());
        // Multiplex: consecutive same-model non-mutation requests already
        // fully pipelined behind this one ride one backend connection as
        // a single in-flight burst instead of strictly alternating
        // write/read per request.
        if keep && conn.has_buffered_request() {
            if let Target::Model(name) = classify(&req) {
                if !is_mutation(&req) {
                    let mut burst = vec![req];
                    let mut bad_next: Option<&'static str> = None;
                    while burst.len() < MUX_DEPTH_CAP && conn.has_buffered_request() {
                        match read_request(&mut conn) {
                            Ok(next) => {
                                let same = !is_mutation(&next)
                                    && next.keep_alive
                                    && matches!(
                                        classify(&next),
                                        Target::Model(ref m) if *m == name
                                    );
                                if same {
                                    burst.push(next);
                                } else {
                                    carry = Some(next);
                                    break;
                                }
                            }
                            Err(msg) => {
                                bad_next = Some(msg);
                                break;
                            }
                        }
                    }
                    served += burst.len() - 1;
                    let keep_last = if bad_next.is_some() {
                        true // the 400 answer below still follows
                    } else {
                        served < MAX_REQUESTS_PER_CONN
                            && (!state.draining.load(Ordering::SeqCst)
                                || carry.is_some()
                                || conn.has_buffered())
                    };
                    let open = if burst.len() == 1 {
                        respond(state, &stream, &burst[0], keep_last)
                    } else {
                        proxy_model_burst(state, &stream, &burst, &name, keep_last)
                    };
                    if let Some(msg) = bad_next {
                        if open {
                            write_response(
                                &stream,
                                "400 Bad Request",
                                JSON,
                                &error_json(msg),
                                false,
                            );
                        }
                        dirty_close = true;
                        break;
                    }
                    if !open {
                        break;
                    }
                    continue;
                }
            }
        }
        if !respond(state, &stream, &req, keep) {
            break;
        }
    }
    // Same RST-avoidance as the backend server: never close with unread
    // client bytes without a half-close drain.
    if dirty_close || conn.has_buffered() {
        let _ = stream.shutdown(std::net::Shutdown::Write);
        let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
        let mut sink = [0u8; 4096];
        let mut r = &stream;
        let deadline = Instant::now() + Duration::from_millis(250);
        while Instant::now() < deadline {
            match Read::read(&mut r, &mut sink) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
        }
    }
}

/// Answer one request; returns whether the client connection stays open.
fn respond(state: &RouterState, client: &TcpStream, req: &HttpRequest, keep: bool) -> bool {
    match classify(req) {
        Target::Model(name) => {
            if is_mutation(req) {
                if let Some((status, ct, body)) =
                    bearer_auth_failure(state.auth_token.as_deref(), req)
                {
                    write_response(client, status, ct, &body, keep);
                    return keep;
                }
            }
            proxy_model(state, client, req, &name, keep)
        }
        Target::FleetModels => finish(client, fleet_models(state), keep),
        Target::FleetHealth => finish(client, fleet_health(state), keep),
        Target::FleetStats => finish(client, fleet_stats(state), keep),
        Target::Bad(msg) => finish(client, ("400 Bad Request", JSON, error_json(msg)), keep),
        Target::NotFound => finish(
            client,
            ("404 Not Found", JSON, error_json("no such endpoint")),
            keep,
        ),
    }
}

fn finish(client: &TcpStream, resp: Response, keep: bool) -> bool {
    let (status, ct, body) = resp;
    write_response(client, status, ct, &body, keep);
    keep
}

// ---------------------------------------------------------------------------
// The proxy path
// ---------------------------------------------------------------------------

/// A parsed backend response head, ready to relay.
struct ProxyHead {
    code: u16,
    /// The raw status line (no terminator).
    status_line: String,
    /// Header lines to relay verbatim (no terminators; `Connection`
    /// excluded — the router speaks for itself there).
    headers: Vec<String>,
    content_len: usize,
    chunked: bool,
    /// Whether the *backend* connection survives this exchange.
    keep_alive: bool,
}

fn io_err(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string())
}

fn read_proxy_head(reader: &mut BufReader<&TcpStream>) -> std::io::Result<ProxyHead> {
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let code: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| io_err("bad backend status line"))?;
    let mut head = ProxyHead {
        code,
        status_line: status_line.trim_end().to_string(),
        headers: Vec::with_capacity(4),
        content_len: 0,
        chunked: false,
        keep_alive: true,
    };
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let t = h.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                head.content_len = v.trim().parse().map_err(|_| io_err("bad content-length"))?;
            } else if k.eq_ignore_ascii_case("transfer-encoding") {
                head.chunked = !v.trim().eq_ignore_ascii_case("identity");
            } else if k.eq_ignore_ascii_case("connection") {
                head.keep_alive = !v.trim().eq_ignore_ascii_case("close");
                continue; // not relayed
            }
        }
        head.headers.push(t.to_string());
    }
    Ok(head)
}

/// Serialize the client's request onto a backend connection, forwarding
/// the router token (or, without one, the client's own `Authorization`).
fn write_proxy_request(
    stream: &TcpStream,
    req: &HttpRequest,
    token: Option<&str>,
) -> std::io::Result<()> {
    let target = if req.query.is_empty() {
        req.path.clone()
    } else {
        format!("{}?{}", req.path, req.query)
    };
    let auth = match token {
        Some(t) => format!("Authorization: Bearer {t}\r\n"),
        None => req
            .authorization
            .as_ref()
            .map(|v| format!("Authorization: {v}\r\n"))
            .unwrap_or_default(),
    };
    let mut w = stream;
    write!(
        w,
        "{} {target} HTTP/1.1\r\nHost: backend\r\nContent-Length: {}\r\n{auth}Connection: keep-alive\r\n\r\n{}",
        req.method,
        req.body.len(),
        req.body
    )?;
    w.flush()
}

/// Copy exactly `n` body bytes backend → client in bounded pieces.
fn copy_n(
    reader: &mut BufReader<&TcpStream>,
    client: &TcpStream,
    mut n: usize,
) -> std::io::Result<()> {
    let mut buf = [0u8; COPY_BUF];
    let mut w = client;
    while n > 0 {
        let take = n.min(COPY_BUF);
        reader.read_exact(&mut buf[..take])?;
        w.write_all(&buf[..take])?;
        n -= take;
    }
    Ok(())
}

/// Relay a chunked body verbatim, chunk by chunk (sizes re-emitted as
/// received), so a streaming predict-batch passes through without ever
/// being buffered whole.
fn relay_chunked(reader: &mut BufReader<&TcpStream>, client: &TcpStream) -> std::io::Result<()> {
    loop {
        let mut size_line = String::new();
        if reader.read_line(&mut size_line)? == 0 {
            return Err(io_err("eof inside chunked body"));
        }
        let size =
            usize::from_str_radix(size_line.trim().split(';').next().unwrap_or("").trim(), 16)
                .map_err(|_| io_err("bad chunk size"))?;
        let mut w = client;
        w.write_all(size_line.as_bytes())?;
        if size == 0 {
            let mut end = String::new();
            reader.read_line(&mut end)?;
            w.write_all(end.as_bytes())?;
            w.flush()?;
            return Ok(());
        }
        copy_n(reader, client, size)?;
        let mut crlf = [0u8; 2];
        reader.read_exact(&mut crlf)?;
        w.write_all(&crlf)?;
    }
}

/// Relay one backend response (head + body, either framing) to the
/// client, with the router's own `Connection` header.
fn relay_response(
    reader: &mut BufReader<&TcpStream>,
    client: &TcpStream,
    head: &ProxyHead,
    client_keep: bool,
) -> std::io::Result<()> {
    let mut out = Vec::with_capacity(256);
    let _ = write!(out, "{}\r\n", head.status_line);
    for h in &head.headers {
        let _ = write!(out, "{h}\r\n");
    }
    let conn = if client_keep { "keep-alive" } else { "close" };
    let _ = write!(out, "Connection: {conn}\r\n\r\n");
    {
        let mut w = client;
        w.write_all(&out)?;
    }
    if head.chunked {
        relay_chunked(reader, client)?;
    } else {
        copy_n(reader, client, head.content_len)?;
    }
    let mut w = client;
    w.flush()
}

/// Absorb a small non-chunked body (a backend `503` being retried) so
/// the connection can be reused; `None` means the connection must be
/// dropped instead.
fn read_small_body(reader: &mut BufReader<&TcpStream>, head: &ProxyHead) -> Option<Vec<u8>> {
    if head.chunked || head.content_len > DISCARD_CAP {
        return None;
    }
    let mut body = vec![0u8; head.content_len];
    reader.read_exact(&mut body).ok()?;
    Some(body)
}

/// Proxy one model-scoped request to the ring owner, failing over along
/// the ring under the retry budget. Returns whether the client
/// connection stays open.
fn proxy_model(
    state: &RouterState,
    client: &TcpStream,
    req: &HttpRequest,
    name: &str,
    keep: bool,
) -> bool {
    // Work off one placement snapshot for the whole request: a
    // concurrent backend reconfiguration swaps the set under us, but
    // this request's candidate indices stay valid against its snapshot.
    let (order, backends) = {
        let g = state.placement.read().unwrap_or_else(|e| e.into_inner());
        (g.ring.order(name), g.backends.clone())
    };
    let healthy: Vec<usize> = order
        .iter()
        .copied()
        .filter(|&i| backends[i].healthy.load(Ordering::Relaxed))
        .collect();
    // When nobody is (known) healthy, try the full ring anyway: the
    // health view may be stale and a refusal must come from evidence.
    let candidates = if healthy.is_empty() { order } else { healthy };
    let attempts = state.retry_budget + 1;
    let mut last_refusal: Option<Vec<u8>> = None;
    for attempt in 0..attempts {
        if attempt > 0 {
            state.retries.fetch_add(1, Ordering::Relaxed);
            // Back off before walking to the next candidate: a blip
            // (backend restarting, capacity shed) often clears within
            // milliseconds, and hammering the ring amplifies it.
            let wait = failover_backoff(name, attempt);
            state.backoff_ms.fetch_add(wait.as_millis() as u64, Ordering::Relaxed);
            std::thread::sleep(wait);
        }
        let b = &backends[candidates[attempt % candidates.len()]];
        let (stream, pooled) = match b.take_conn() {
            Some(s) => (s, true),
            None => match connect_backend(&b.addr(), state.proxy_timeout) {
                Some(s) => (s, false),
                None => {
                    b.mark_down();
                    continue;
                }
            },
        };
        if write_proxy_request(&stream, req, state.auth_token.as_deref()).is_err() {
            // A stale pooled connection failing is no verdict on the
            // backend; a fresh one failing is.
            if pooled {
                b.clear_pool();
            } else {
                b.mark_down();
            }
            continue;
        }
        let mut reader = BufReader::new(&stream);
        let head = match read_proxy_head(&mut reader) {
            Ok(h) => h,
            Err(_) => {
                if pooled {
                    b.clear_pool();
                } else {
                    b.mark_down();
                }
                continue;
            }
        };
        if head.code == 503 && attempt + 1 < attempts {
            // The backend refused (capacity, open circuit, draining): a
            // ring neighbor can lazily spawn the model, so spend a
            // retry. Remember the refusal — it is the honest answer if
            // every neighbor also refuses.
            if let Some(body) = read_small_body(&mut reader, &head) {
                if head.keep_alive {
                    b.put_conn(stream);
                }
                last_refusal = Some(body);
            }
            continue;
        }
        match relay_response(&mut reader, client, &head, keep) {
            Ok(()) => {
                b.healthy.store(true, Ordering::Relaxed);
                b.proxied.fetch_add(1, Ordering::Relaxed);
                state.proxied.fetch_add(1, Ordering::Relaxed);
                if head.keep_alive {
                    b.put_conn(stream);
                }
                return keep;
            }
            // Mid-relay failure: the client may hold partial bytes, so
            // a retry would corrupt the stream — close instead.
            Err(_) => return false,
        }
    }
    // Budget exhausted. Relay the last backend refusal when one was
    // captured; otherwise every candidate was unreachable.
    let body = match last_refusal {
        Some(b) => String::from_utf8_lossy(&b).into_owned(),
        None => error_json(&format!("no healthy backend for model '{name}'")),
    };
    let mut out = Vec::with_capacity(body.len() + 128);
    append_response_extra(&mut out, "503 Service Unavailable", JSON, &body, keep, RETRY_AFTER);
    let mut w = client;
    let _ = w.write_all(&out);
    let _ = w.flush();
    keep
}

/// Serve a burst request-by-request through [`proxy_model`] (the path
/// that owns retry/failover semantics). All but the last response are
/// keep-alive (more of the pipeline follows).
fn proxy_sequential(
    state: &RouterState,
    client: &TcpStream,
    reqs: &[HttpRequest],
    name: &str,
    keep_last: bool,
) -> bool {
    let mut open = true;
    for (k, r) in reqs.iter().enumerate() {
        open = proxy_model(state, client, r, name, k + 1 < reqs.len() || keep_last);
        if !open {
            break;
        }
    }
    open
}

/// Proxy a burst of same-model pipelined requests multiplexed over ONE
/// backend connection: every request is written back-to-back (the pooled
/// connection carries the whole burst in flight), then the responses are
/// relayed in order. Any failure before the first response byte reaches
/// the client falls back to the sequential per-request path, which owns
/// retry/failover; a mid-relay failure closes the client connection
/// exactly like the single-request path. Returns whether the client
/// connection stays open.
fn proxy_model_burst(
    state: &RouterState,
    client: &TcpStream,
    burst: &[HttpRequest],
    name: &str,
    keep_last: bool,
) -> bool {
    let n = burst.len();
    let (order, backends) = {
        let g = state.placement.read().unwrap_or_else(|e| e.into_inner());
        (g.ring.order(name), g.backends.clone())
    };
    let healthy: Vec<usize> = order
        .iter()
        .copied()
        .filter(|&i| backends[i].healthy.load(Ordering::Relaxed))
        .collect();
    let candidates = if healthy.is_empty() { order } else { healthy };
    let b = &backends[candidates[0]];
    let (stream, pooled) = match b.take_conn() {
        Some(s) => (s, true),
        None => match connect_backend(&b.addr(), state.proxy_timeout) {
            Some(s) => (s, false),
            None => {
                b.mark_down();
                return proxy_sequential(state, client, burst, name, keep_last);
            }
        },
    };
    for r in burst {
        if write_proxy_request(&stream, r, state.auth_token.as_deref()).is_err() {
            if pooled {
                b.clear_pool();
            } else {
                b.mark_down();
            }
            return proxy_sequential(state, client, burst, name, keep_last);
        }
    }
    let mut reader = BufReader::new(&stream);
    let mut backend_alive = true;
    for k in 0..n {
        let head = match read_proxy_head(&mut reader) {
            Ok(h) => h,
            Err(_) => {
                if k == 0 {
                    // No client bytes written yet: drop this connection
                    // and redo the whole burst with failover.
                    if pooled {
                        b.clear_pool();
                    } else {
                        b.mark_down();
                    }
                    return proxy_sequential(state, client, burst, name, keep_last);
                }
                // Mid-burst: delivered responses stand; the unanswered
                // tail re-proxies on a fresh connection.
                return proxy_sequential(state, client, &burst[k..], name, keep_last);
            }
        };
        if head.code == 503 && k == 0 {
            // The owner refused the burst head (draining, capacity):
            // drop the connection — its queued refusals with it — and
            // let the per-request path walk the ring with its retry
            // budget.
            return proxy_sequential(state, client, burst, name, keep_last);
        }
        match relay_response(&mut reader, client, &head, k + 1 < n || keep_last) {
            Ok(()) => {
                b.healthy.store(true, Ordering::Relaxed);
                b.proxied.fetch_add(1, Ordering::Relaxed);
                state.proxied.fetch_add(1, Ordering::Relaxed);
                state.mux_requests.fetch_add(1, Ordering::Relaxed);
            }
            // The client may hold partial bytes: close, never retry.
            Err(_) => return false,
        }
        if !head.keep_alive {
            backend_alive = false;
            if k + 1 < n {
                // Backend hung up mid-pipeline (per-connection request
                // cap); the unanswered tail re-proxies fresh.
                return proxy_sequential(state, client, &burst[k + 1..], name, keep_last);
            }
        }
    }
    state.mux_batches.fetch_add(1, Ordering::Relaxed);
    if backend_alive {
        b.put_conn(stream);
    }
    keep_last
}

fn connect_backend(addr: &str, read_timeout: Duration) -> Option<TcpStream> {
    let sa: SocketAddr = addr.parse().ok()?;
    let stream = TcpStream::connect_timeout(&sa, CONNECT_TIMEOUT).ok()?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(read_timeout)).ok();
    Some(stream)
}

// ---------------------------------------------------------------------------
// Fleet (fan-out) routes
// ---------------------------------------------------------------------------

/// Pull every `"name":"…"` out of a backend `/v1/models` document
/// (registry names are validated identifiers, so no JSON escapes occur).
fn scan_model_names(doc: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = doc;
    while let Some(at) = rest.find("\"name\":\"") {
        rest = &rest[at + 8..];
        if let Some(end) = rest.find('"') {
            let name = &rest[..end];
            if !out.iter().any(|n| n == name) {
                out.push(name.to_string());
            }
            rest = &rest[end..];
        } else {
            break;
        }
    }
    out
}

/// `GET /v1/models`: fan out to every backend and aggregate — `models`
/// is the union of model names across the fleet, `per_backend` carries
/// each backend's own listing verbatim.
fn fleet_models(state: &RouterState) -> Response {
    state.fanouts.fetch_add(1, Ordering::Relaxed);
    let backends = state.backends();
    let mut names: Vec<String> = Vec::new();
    let mut per = Vec::with_capacity(backends.len());
    for (i, b) in backends.iter().enumerate() {
        let addr = b.addr();
        let doc = addr
            .parse::<SocketAddr>()
            .ok()
            .and_then(|sa| {
                http_request_with_auth(&sa, "GET", "/v1/models", "", state.auth_token.as_deref())
                    .ok()
            })
            .filter(|(code, _)| *code == 200);
        match doc {
            Some((_, body)) => {
                for n in scan_model_names(&body) {
                    if !names.contains(&n) {
                        names.push(n);
                    }
                }
                per.push(format!(
                    "{{\"backend\":{i},\"addr\":\"{}\",\"reachable\":true,\"listing\":{body}}}",
                    json_escape(&addr)
                ));
            }
            None => per.push(format!(
                "{{\"backend\":{i},\"addr\":\"{}\",\"reachable\":false}}",
                json_escape(&addr)
            )),
        }
    }
    names.sort();
    let quoted: Vec<String> = names
        .iter()
        .map(|n| format!("\"{}\"", json_escape(n)))
        .collect();
    (
        "200 OK",
        JSON,
        format!(
            "{{\"router\":true,\"backends\":{},\"models\":[{}],\"per_backend\":[{}]}}",
            backends.len(),
            quoted.join(","),
            per.join(",")
        ),
    )
}

/// `GET /healthz`: probe the fleet now. `ok` (200) while at least one
/// backend is up — a router with a live shard keeps serving what it can
/// — `degraded` (503) when none are, `draining` (503) during a drain.
/// Per-backend lines follow the verdict either way.
fn fleet_health(state: &RouterState) -> Response {
    const PLAIN: &str = "text/plain";
    if state.draining.load(Ordering::SeqCst) {
        return ("503 Service Unavailable", PLAIN, "draining\n".to_string());
    }
    let up = check_round(state);
    let mut body = String::from(if up == 0 { "degraded\n" } else { "ok\n" });
    for (i, b) in state.backends().iter().enumerate() {
        let status = if b.healthy.load(Ordering::Relaxed) {
            "up"
        } else {
            "down"
        };
        body.push_str(&format!("backend {i} {}: {status}\n", b.addr()));
    }
    if up == 0 {
        ("503 Service Unavailable", PLAIN, body)
    } else {
        ("200 OK", PLAIN, body)
    }
}

/// `GET /stats`: the router's own counters plus per-backend health and
/// traffic.
fn fleet_stats(state: &RouterState) -> Response {
    let per: Vec<String> = state
        .backends()
        .iter()
        .enumerate()
        .map(|(i, b)| {
            format!(
                "{{\"index\":{i},\"addr\":\"{}\",\"healthy\":{},\"proxied\":{},\"errors\":{}}}",
                json_escape(&b.addr()),
                b.healthy.load(Ordering::Relaxed),
                b.proxied.load(Ordering::Relaxed),
                b.errors.load(Ordering::Relaxed)
            )
        })
        .collect();
    (
        "200 OK",
        JSON,
        format!(
            "{{\"router\":{{\"proxied\":{},\"retries\":{},\"backoff_ms\":{},\"fanouts\":{},\
             \"mux_batches\":{},\"mux_requests\":{}}},\"backends\":[{}]}}",
            state.proxied.load(Ordering::Relaxed),
            state.retries.load(Ordering::Relaxed),
            state.backoff_ms.load(Ordering::Relaxed),
            state.fanouts.load(Ordering::Relaxed),
            state.mux_batches.load(Ordering::Relaxed),
            state.mux_requests.load(Ordering::Relaxed),
            per.join(",")
        ),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_matches_reference_vectors() {
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn ring_placement_is_stable_and_covers_backends() {
        let a = Ring::new(4);
        let b = Ring::new(4);
        let mut hit = [0usize; 4];
        for k in 0..200 {
            let key = format!("model-{k}");
            let owner = a.primary(&key);
            assert_eq!(owner, b.primary(&key), "placement must be deterministic");
            hit[owner] += 1;
        }
        for (i, n) in hit.iter().enumerate() {
            assert!(*n > 0, "backend {i} owns no keys out of 200");
        }
    }

    #[test]
    fn ring_order_lists_every_backend_once() {
        let ring = Ring::new(5);
        for k in 0..20 {
            let order = ring.order(&format!("m{k}"));
            let mut sorted = order.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(order.len(), 5, "{order:?}");
            assert_eq!(sorted.len(), 5, "{order:?}");
        }
    }

    #[test]
    fn ring_growth_remaps_only_a_fraction_of_keys() {
        let three = Ring::new(3);
        let four = Ring::new(4);
        let total = 300;
        let moved = (0..total)
            .filter(|k| {
                let key = format!("model-{k}");
                three.primary(&key) != four.primary(&key)
            })
            .count();
        // Consistent hashing: adding a backend remaps roughly 1/4 of
        // keys, not all of them. Allow slack, but far below a rehash.
        assert!(moved < total / 2, "{moved}/{total} keys moved on 3->4");
    }

    #[test]
    fn empty_ring_owns_nothing() {
        let ring = Ring::new(0);
        assert!(ring.order("m").is_empty());
    }

    fn req(method: &str, path: &str) -> HttpRequest {
        HttpRequest {
            method: method.to_string(),
            path: path.to_string(),
            query: String::new(),
            body: String::new(),
            keep_alive: true,
            authorization: None,
        }
    }

    #[test]
    fn classify_splits_model_fleet_and_legacy_routes() {
        assert!(matches!(
            classify(&req("POST", "/v1/models/m/predict")),
            Target::Model(n) if n == "m"
        ));
        assert!(matches!(
            classify(&req("GET", "/v1/models/m/stats")),
            Target::Model(n) if n == "m"
        ));
        assert!(matches!(
            classify(&req("GET", "/v1/models")),
            Target::FleetModels
        ));
        assert!(matches!(classify(&req("GET", "/healthz")), Target::FleetHealth));
        assert!(matches!(classify(&req("GET", "/stats")), Target::FleetStats));
        assert!(matches!(classify(&req("POST", "/predict")), Target::Bad(_)));
        assert!(matches!(classify(&req("POST", "/reload")), Target::Bad(_)));
        assert!(matches!(classify(&req("GET", "/nope")), Target::NotFound));
    }

    #[test]
    fn mutation_detection_guards_lifecycle_actions_only() {
        assert!(is_mutation(&req("POST", "/v1/models/m/reload")));
        assert!(is_mutation(&req("POST", "/v1/models/m/evict")));
        assert!(is_mutation(&req("POST", "/v1/models/m/promote")));
        assert!(is_mutation(&req("POST", "/v1/models/m/rollback")));
        assert!(!is_mutation(&req("POST", "/v1/models/m/predict")));
        assert!(!is_mutation(&req("GET", "/v1/models/m/stats")));
        assert!(!is_mutation(&req("GET", "/v1/models")));
    }

    #[test]
    fn failover_backoff_is_deterministic_bounded_and_grows() {
        let base = BACKOFF_BASE.as_millis() as u64;
        let cap = BACKOFF_CAP;
        for attempt in 1..=6usize {
            let d = failover_backoff("modelA", attempt);
            assert_eq!(
                d,
                failover_backoff("modelA", attempt),
                "same key+attempt must back off identically"
            );
            let step = base << attempt.saturating_sub(1).min(4);
            assert!(d >= Duration::from_millis(step).min(cap), "{attempt}: {d:?}");
            assert!(d <= Duration::from_millis(step + step / 2).min(cap), "{attempt}: {d:?}");
            assert!(d <= cap);
        }
        // While the step still doubles (attempts 1–5), successive
        // attempts never shrink: min(step·2) ≥ max(step·1.5).
        let mut prev = Duration::ZERO;
        for attempt in 1..=5usize {
            let d = failover_backoff("modelB", attempt);
            assert!(d >= prev, "attempt {attempt}: {d:?} < {prev:?}");
            prev = d;
        }
    }

    #[test]
    fn update_backends_matches_slots_and_rebuilds_the_ring() {
        // Dead addresses: probes fail fast, nothing listens there.
        let cfg = RouterConfig {
            backends: vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()],
            ..RouterConfig::default()
        };
        let router = Router::start("127.0.0.1:0", cfg).unwrap();
        assert_eq!(router.backend_addrs().len(), 2);

        // No change: free, nothing reported.
        let same: Vec<String> = vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()];
        let u = router.update_backends(&same).unwrap();
        assert!(!u.changed(), "{u:?}");

        // Append one: added, unhealthy until a probe proves it.
        let grown: Vec<String> = vec![
            "127.0.0.1:1".into(),
            "127.0.0.1:2".into(),
            "127.0.0.1:3".into(),
        ];
        let u = router.update_backends(&grown).unwrap();
        assert_eq!((u.added, u.removed, u.repointed), (1, 0, 0));
        assert_eq!(router.backend_addrs().len(), 3);
        assert!(!router.backend_healthy(2));

        // Repoint slot 1; ring size unchanged so placement of the other
        // slots survives bit-identically.
        let place_before: Vec<usize> = (0..50).map(|k| router.place(&format!("m{k}"))).collect();
        let repointed: Vec<String> = vec![
            "127.0.0.1:1".into(),
            "127.0.0.1:9".into(),
            "127.0.0.1:3".into(),
        ];
        let u = router.update_backends(&repointed).unwrap();
        assert_eq!((u.added, u.removed, u.repointed), (0, 0, 1));
        let place_after: Vec<usize> = (0..50).map(|k| router.place(&format!("m{k}"))).collect();
        assert_eq!(place_before, place_after);

        // Shrink back to one: two removed, traffic to them stops.
        let shrunk: Vec<String> = vec!["127.0.0.1:1".into()];
        let u = router.update_backends(&shrunk).unwrap();
        assert_eq!((u.added, u.removed, u.repointed), (0, 2, 0));
        assert_eq!(router.backend_addrs(), vec!["127.0.0.1:1".to_string()]);
        assert_eq!(router.place("anything"), 0);

        // An empty list is refused and changes nothing.
        assert!(router.update_backends(&[]).is_err());
        assert_eq!(router.backend_addrs().len(), 1);
    }

    #[test]
    fn model_name_scan_finds_the_union_inputs() {
        let doc = r#"{"models":[{"name":"a","loaded":true},{"name":"b","loaded":false},{"name":"a"}]}"#;
        assert_eq!(scan_model_names(doc), vec!["a", "b"]);
        assert!(scan_model_names("{}").is_empty());
    }
}
