//! Model persistence and the named-model registry.
//!
//! The seed repo could only persist a single finest-level [`SvmModel`]
//! as a LibSVM-style line file. Serving the multilevel framework needs
//! more: the AML-SVM line of work keeps per-level / per-class ensembles
//! around at prediction time, so this module extends the line protocol
//! into a versioned multi-section format that round-trips
//!
//! * a bare [`SvmModel`] (`kind = svm`),
//! * a full [`MlsvmModel`] — finest model + final [`SvmParams`] + the
//!   per-level metadata (`kind = mlsvm`),
//! * a one-vs-rest [`MulticlassModel`] with per-class sections, including
//!   failed class jobs (`kind = multiclass`),
//! * a best-levels voting [`EnsembleModel`] from adaptive refinement
//!   (`kind = ensemble`, v2 binary only).
//!
//! Three on-disk formats coexist:
//!
//! * **v2 binary** (the current write format, [`crate::serve::binary`]):
//!   length-prefixed little-endian sections; raw IEEE-754 bits, so
//!   decisions round-trip bit for bit and large SV sets load at I/O
//!   speed instead of float-parse speed;
//! * **v1 text** — header line `mlsvm-model v1 <kind>`, shortest-
//!   round-trip float formatting (also bit-exact, but slow to parse);
//! * **legacy** — bare single-`SvmModel` line files from before the
//!   registry existed.
//!
//! [`load_artifact`] sniffs the format (binary magic, then text header,
//! then legacy) so every model file ever saved by this repo still loads;
//! on Unix it memory-maps the file read-only so the v2 parser copies the
//! SV matrix out of the page cache directly, without a transient
//! whole-file heap buffer.
//! [`save_artifact`] writes v2; [`save_artifact_v1`] keeps the text
//! writer alive for migration tests and the v1-vs-v2 load benchmark.
//!
//! [`Registry`] is a directory of named `<name>.model` files with
//! save / load / list / migrate operations — the unit the serving layer
//! loads and hot-reloads from.
//!
//! **Versioning:** overwriting a name archives the displaced artifact as
//! a dot-prefixed version file (`.{name}.{n}.model`, invisible to
//! [`Registry::list`] like every other dot-file in the directory), so
//! the previous model stays reachable for [`Registry::rollback`].
//! [`Registry::history`] lists the archived versions oldest-first; the
//! registry keeps the last [`DEFAULT_KEEP_VERSIONS`] per name (tunable
//! via [`Registry::set_keep_versions`]) and prunes older ones on save.

use crate::coordinator::jobs::{ClassJob, MulticlassModel};
use crate::error::{Error, Result};
use crate::mlsvm::ensemble::EnsembleModel;
use crate::mlsvm::trainer::{LevelStat, MlsvmModel};
use crate::serve::binary;
use crate::serve::faults::{FaultPlan, LoadFault};
use crate::svm::model::SvmModel;
use crate::svm::smo::{SvmParams, TrainStats};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Magic token opening every versioned **text** model file.
pub const MAGIC: &str = "mlsvm-model";
/// Text format version (the binary format's version lives in
/// [`crate::serve::binary::BIN_VERSION`]).
pub const VERSION: u32 = 1;
/// Registry file extension.
pub const EXTENSION: &str = "model";
/// How many archived versions a save keeps per model name by default.
pub const DEFAULT_KEEP_VERSIONS: usize = 3;

/// On-disk format of a model file, as sniffed by [`detect_format`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelFormat {
    /// v2 length-prefixed binary sections.
    V2Binary,
    /// v1 `mlsvm-model` text format.
    V1Text,
    /// Pre-registry bare `SvmModel` line file.
    LegacyLines,
}

impl std::fmt::Display for ModelFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ModelFormat::V2Binary => write!(f, "v2-binary"),
            ModelFormat::V1Text => write!(f, "v1-text"),
            ModelFormat::LegacyLines => write!(f, "legacy-lines"),
        }
    }
}

/// Sniff the on-disk format of a model file from its first bytes.
pub fn detect_format(path: impl AsRef<Path>) -> Result<ModelFormat> {
    use std::io::Read as _;
    let mut f = std::fs::File::open(path)?;
    let mut head = [0u8; 16];
    let mut n = 0usize;
    while n < head.len() {
        let got = f.read(&mut head[n..])?;
        if got == 0 {
            break;
        }
        n += got;
    }
    let head = &head[..n];
    if binary::is_binary(head) {
        return Ok(ModelFormat::V2Binary);
    }
    if head.starts_with(MAGIC.as_bytes()) {
        return Ok(ModelFormat::V1Text);
    }
    Ok(ModelFormat::LegacyLines)
}

/// Any persistable trained model.
#[derive(Clone, Debug)]
pub enum ModelArtifact {
    /// A bare binary SVM (also what legacy files load as).
    Svm(SvmModel),
    /// A full multilevel model with params and level metadata.
    Mlsvm(MlsvmModel),
    /// A one-vs-rest ensemble.
    Multiclass(MulticlassModel),
    /// A best-levels voting ensemble from adaptive refinement.
    Ensemble(EnsembleModel),
}

impl ModelArtifact {
    /// Format kind token.
    pub fn kind(&self) -> &'static str {
        match self {
            ModelArtifact::Svm(_) => "svm",
            ModelArtifact::Mlsvm(_) => "mlsvm",
            ModelArtifact::Multiclass(_) => "multiclass",
            ModelArtifact::Ensemble(_) => "ensemble",
        }
    }

    /// One-line human description (server banner, `mlsvm serve` log).
    pub fn describe(&self) -> String {
        match self {
            ModelArtifact::Svm(m) => {
                format!("svm: {} SVs, dim {}", m.n_sv(), m.sv.cols())
            }
            ModelArtifact::Mlsvm(m) => format!(
                "mlsvm: {} SVs, dim {}, {} levels",
                m.model.n_sv(),
                m.model.sv.cols(),
                m.level_stats.len()
            ),
            ModelArtifact::Multiclass(mc) => {
                let ok = mc.jobs.iter().filter(|j| j.model.is_some()).count();
                format!("multiclass: {}/{} trained class models", ok, mc.jobs.len())
            }
            ModelArtifact::Ensemble(e) => format!(
                "ensemble: {} voting members, dim {}",
                e.n_members(),
                e.dim()
            ),
        }
    }
}

// ---------------------------------------------------------------------------
// Writers
// ---------------------------------------------------------------------------

fn write_mlsvm_body<W: Write>(w: &mut W, m: &MlsvmModel) -> Result<()> {
    let p = &m.params;
    writeln!(
        w,
        "params c_pos {} c_neg {} eps {} max_iter {} cache_bytes {} shrinking {}",
        p.c_pos,
        p.c_neg,
        p.eps,
        p.max_iter,
        p.cache_bytes,
        p.shrinking as u8
    )?;
    writeln!(w, "depths {} {}", m.depths.0, m.depths.1)?;
    writeln!(w, "levels {}", m.level_stats.len())?;
    for s in &m.level_stats {
        let cv = s
            .cv_gmean
            .map(|g| g.to_string())
            .unwrap_or_else(|| "-".to_string());
        writeln!(
            w,
            "level {} {} train {} sv {} ud {} secs {} cv {cv} iters {} gap {} hits {} misses {} warm {} udsecs {}",
            s.levels.0,
            s.levels.1,
            s.train_size,
            s.n_sv,
            s.ud_used as u8,
            s.seconds,
            s.solver.iterations,
            s.solver.gap,
            s.solver.cache_hits,
            s.solver.cache_misses,
            s.solver.warm_started as u8,
            s.ud_seconds
        )?;
    }
    writeln!(w, "model")?;
    m.model.write_text(w)
}

fn write_multiclass_body<W: Write>(w: &mut W, mc: &MulticlassModel) -> Result<()> {
    writeln!(w, "classes {}", mc.jobs.len())?;
    for job in &mc.jobs {
        match (&job.model, &job.error) {
            (Some(m), _) => {
                writeln!(
                    w,
                    "class {} secs {} pos {} neg {} status ok",
                    job.class_id, job.seconds, job.sizes.0, job.sizes.1
                )?;
                write_mlsvm_body(w, m)?;
            }
            (None, err) => {
                // Newlines would corrupt the line protocol, and an empty
                // message would leave the line unparseable (the reader
                // expects a token after `err`).
                let msg = err
                    .as_deref()
                    .unwrap_or("unknown failure")
                    .replace(['\n', '\r'], " ");
                let msg = msg.trim();
                let msg = if msg.is_empty() { "unknown failure" } else { msg };
                writeln!(
                    w,
                    "class {} secs {} pos {} neg {} status err {msg}",
                    job.class_id, job.seconds, job.sizes.0, job.sizes.1
                )?;
            }
        }
    }
    Ok(())
}

/// Write a model file crash-safely: the body goes to a uniquely-named
/// dot-prefixed temp file **in the destination directory** (same
/// filesystem, so the final step is a true rename), is flushed and
/// fsynced, then renamed over `path`. A crash at any point leaves
/// either the old artifact or the new one — never a torn file — and the
/// only possible litter is a dot-prefixed `.tmp` that
/// [`Registry::list`] ignores.
pub fn write_atomic(
    path: &Path,
    write_body: impl FnOnce(&mut BufWriter<std::fs::File>) -> Result<()>,
) -> Result<()> {
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let dir = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p,
        _ => Path::new("."),
    };
    let stem = path
        .file_name()
        .and_then(|s| s.to_str())
        .ok_or_else(|| Error::invalid(format!("bad model path '{}'", path.display())))?;
    let tmp = dir.join(format!(
        ".{stem}.{}-{}.tmp",
        std::process::id(),
        SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    let written: Result<()> = (|| {
        let f = std::fs::File::create(&tmp)?;
        let mut w = BufWriter::new(f);
        write_body(&mut w)?;
        w.flush()?;
        w.get_ref().sync_all()?;
        Ok(())
    })();
    if let Err(e) = written {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e.into());
    }
    Ok(())
}

/// Write `artifact` to `path` in the current (v2 binary) format,
/// crash-safely (temp file + fsync + rename; an interrupted save leaves
/// any previous artifact at `path` untouched).
pub fn save_artifact(path: impl AsRef<Path>, artifact: &ModelArtifact) -> Result<()> {
    write_atomic(path.as_ref(), |w| {
        w.write_all(&binary::write_artifact(artifact))?;
        Ok(())
    })
}

/// Write `artifact` to `path` in the v1 text format (kept for the
/// migration path and the v1-vs-v2 load benchmark; new code should use
/// [`save_artifact`]). Crash-safe the same way `save_artifact` is.
pub fn save_artifact_v1(path: impl AsRef<Path>, artifact: &ModelArtifact) -> Result<()> {
    write_atomic(path.as_ref(), |w| {
        writeln!(w, "{MAGIC} v{VERSION} {}", artifact.kind())?;
        match artifact {
            ModelArtifact::Svm(m) => m.write_text(w)?,
            ModelArtifact::Mlsvm(m) => write_mlsvm_body(w, m)?,
            ModelArtifact::Multiclass(mc) => write_multiclass_body(w, mc)?,
            ModelArtifact::Ensemble(_) => {
                return Err(Error::Serve(
                    "ensemble artifacts have no v1 text format; use save_artifact".into(),
                ))
            }
        }
        Ok(())
    })
}

// ---------------------------------------------------------------------------
// Readers
// ---------------------------------------------------------------------------

fn next<'b>(lines: &mut impl Iterator<Item = &'b str>, what: &str) -> Result<&'b str> {
    lines
        .next()
        .ok_or_else(|| Error::invalid(format!("model file truncated at {what}")))
}

fn num<T: std::str::FromStr>(tok: &str, what: &str) -> Result<T> {
    tok.parse()
        .map_err(|_| Error::invalid(format!("bad {what} '{tok}'")))
}

fn flag(tok: &str, what: &str) -> Result<bool> {
    match tok {
        "0" => Ok(false),
        "1" => Ok(true),
        _ => Err(Error::invalid(format!("bad {what} '{tok}'"))),
    }
}

fn read_mlsvm_body<'b>(lines: &mut impl Iterator<Item = &'b str>) -> Result<MlsvmModel> {
    let pline = next(lines, "params")?;
    let pt: Vec<&str> = pline.split_whitespace().collect();
    let mut params = match pt.as_slice() {
        ["params", "c_pos", cp, "c_neg", cn, "eps", e, "max_iter", mi, "cache_bytes", cb, "shrinking", sh] => {
            SvmParams {
                c_pos: num(cp, "c_pos")?,
                c_neg: num(cn, "c_neg")?,
                eps: num(e, "eps")?,
                max_iter: num(mi, "max_iter")?,
                cache_bytes: num(cb, "cache_bytes")?,
                shrinking: flag(sh, "shrinking")?,
                ..Default::default()
            }
        }
        _ => return Err(Error::invalid(format!("bad params line '{pline}'"))),
    };
    let dline = next(lines, "depths")?;
    let dt: Vec<&str> = dline.split_whitespace().collect();
    let depths = match dt.as_slice() {
        ["depths", dp, dn] => (num(dp, "depth")?, num(dn, "depth")?),
        _ => return Err(Error::invalid(format!("bad depths line '{dline}'"))),
    };
    let lline = next(lines, "levels")?;
    let nlevels: usize = match lline.split_whitespace().collect::<Vec<_>>().as_slice() {
        ["levels", n] => num(n, "level count")?,
        _ => return Err(Error::invalid(format!("bad levels line '{lline}'"))),
    };
    let mut level_stats = Vec::with_capacity(nlevels);
    for k in 0..nlevels {
        let line = next(lines, "level")?;
        let t: Vec<&str> = line.split_whitespace().collect();
        // `udsecs` was appended after the v1 release: lines without it
        // (legacy files) still load, with the field defaulting to 0.
        let stat = match t.as_slice() {
            ["level", lp, ln, "train", n, "sv", sv, "ud", ud, "secs", secs, "cv", cv, "iters", it, "gap", gap, "hits", h, "misses", mi, "warm", wa, rest @ ..] => {
                let ud_seconds = match rest {
                    [] => 0.0,
                    ["udsecs", us] => num(us, "ud seconds")?,
                    _ => return Err(Error::invalid(format!("bad level line {k}: '{line}'"))),
                };
                LevelStat {
                    levels: (num(lp, "level")?, num(ln, "level")?),
                    train_size: num(n, "train size")?,
                    n_sv: num(sv, "sv count")?,
                    ud_used: flag(ud, "ud flag")?,
                    seconds: num(secs, "seconds")?,
                    ud_seconds,
                    cv_gmean: if *cv == "-" {
                        None
                    } else {
                        Some(num(cv, "cv gmean")?)
                    },
                    solver: TrainStats {
                        iterations: num(it, "iterations")?,
                        gap: num(gap, "gap")?,
                        cache_hits: num(h, "cache hits")?,
                        cache_misses: num(mi, "cache misses")?,
                        warm_started: flag(wa, "warm flag")?,
                    },
                }
            }
            _ => return Err(Error::invalid(format!("bad level line {k}: '{line}'"))),
        };
        level_stats.push(stat);
    }
    let mline = next(lines, "model")?;
    if mline.trim() != "model" {
        return Err(Error::invalid(format!("expected 'model', got '{mline}'")));
    }
    let model = SvmModel::parse_lines(lines)?;
    params.kernel = model.kernel;
    Ok(MlsvmModel {
        model,
        params,
        level_stats,
        depths,
    })
}

fn read_multiclass_body<'b>(lines: &mut impl Iterator<Item = &'b str>) -> Result<MulticlassModel> {
    let cline = next(lines, "classes")?;
    let nclasses: usize = match cline.split_whitespace().collect::<Vec<_>>().as_slice() {
        ["classes", n] => num(n, "class count")?,
        _ => return Err(Error::invalid(format!("bad classes line '{cline}'"))),
    };
    let mut jobs = Vec::with_capacity(nclasses);
    for _ in 0..nclasses {
        let line = next(lines, "class")?;
        let t: Vec<&str> = line.splitn(11, ' ').collect();
        let job = match t.as_slice() {
            ["class", id, "secs", secs, "pos", p, "neg", n, "status", "ok"] => {
                let model = read_mlsvm_body(lines)?;
                ClassJob {
                    class_id: num(id, "class id")?,
                    model: Some(model),
                    error: None,
                    seconds: num(secs, "seconds")?,
                    sizes: (num(p, "pos size")?, num(n, "neg size")?),
                }
            }
            ["class", id, "secs", secs, "pos", p, "neg", n, "status", "err", msg] => ClassJob {
                class_id: num(id, "class id")?,
                model: None,
                error: Some(msg.to_string()),
                seconds: num(secs, "seconds")?,
                sizes: (num(p, "pos size")?, num(n, "neg size")?),
            },
            _ => return Err(Error::invalid(format!("bad class line '{line}'"))),
        };
        jobs.push(job);
    }
    Ok(MulticlassModel { jobs })
}

/// Load any model file: v2 binary, v1 text (`mlsvm-model v1 ...`), or
/// legacy single-`SvmModel` line files — the format is sniffed from the
/// first bytes.
///
/// On Unix the file is memory-mapped read-only instead of copied into a
/// heap buffer, so the dominant section of a large v2 artifact — the raw
/// little-endian SV matrix — streams from the page cache straight into
/// the model's own storage with one copy total and no transient
/// whole-file allocation. Zero-length files and platforms (or
/// pseudo-files) where `mmap` fails fall back to an ordinary read.
pub fn load_artifact(path: impl AsRef<Path>) -> Result<ModelArtifact> {
    parse_artifact(&map_or_read(path.as_ref())?)
}

/// Raw-libc read-only file mapping (the crate is dependency-free, so no
/// `memmap2`): `mmap(PROT_READ, MAP_PRIVATE)` on open, `munmap` on drop.
#[cfg(unix)]
mod mmap {
    use std::os::unix::io::AsRawFd;

    extern "C" {
        fn mmap(
            addr: *mut core::ffi::c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut core::ffi::c_void;
        fn munmap(addr: *mut core::ffi::c_void, len: usize) -> i32;
    }

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    /// A read-only mapping of a whole file, unmapped on drop.
    pub struct Mapping {
        ptr: *mut core::ffi::c_void,
        len: usize,
    }

    impl Mapping {
        /// Map `len` bytes of `file` (None on any mmap failure — the
        /// caller falls back to a buffered read). `len` must be > 0:
        /// zero-length mappings are an `EINVAL` by spec.
        pub fn map(file: &std::fs::File, len: usize) -> Option<Mapping> {
            let ptr = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    len,
                    PROT_READ,
                    MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            // MAP_FAILED is (void*)-1, not null.
            if ptr as usize == usize::MAX {
                None
            } else {
                Some(Mapping { ptr, len })
            }
        }
    }

    impl Drop for Mapping {
        fn drop(&mut self) {
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }

    impl std::ops::Deref for Mapping {
        type Target = [u8];
        fn deref(&self) -> &[u8] {
            // The mapping is private, read-only, and lives exactly as
            // long as `self`; a concurrent writer cannot tear it because
            // every registry publish goes through rename (`write_atomic`),
            // which leaves the mapped inode untouched.
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }
    }
}

/// Bytes of a model file: memory-mapped where possible, owned otherwise.
enum FileBytes {
    #[cfg(unix)]
    Mapped(mmap::Mapping),
    Owned(Vec<u8>),
}

impl std::ops::Deref for FileBytes {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        match self {
            #[cfg(unix)]
            FileBytes::Mapped(m) => m,
            FileBytes::Owned(v) => v,
        }
    }
}

fn map_or_read(path: &Path) -> Result<FileBytes> {
    #[cfg(unix)]
    {
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        if len > 0 && len <= usize::MAX as u64 {
            if let Some(m) = mmap::Mapping::map(&file, len as usize) {
                return Ok(FileBytes::Mapped(m));
            }
        }
        // Empty files (still a parse error, but a *graceful* one) and
        // unmappable pseudo-files fall through to the owned read.
    }
    Ok(FileBytes::Owned(std::fs::read(path)?))
}

/// Parse an already-read model byte stream (the body of
/// [`load_artifact`], split out so the fault-injection truncation path
/// can corrupt the bytes between read and parse).
fn parse_artifact(bytes: &[u8]) -> Result<ModelArtifact> {
    if binary::is_binary(bytes) {
        return binary::read_artifact(bytes);
    }
    let text = std::str::from_utf8(bytes)
        .map_err(|_| Error::invalid("model file is neither v2 binary nor UTF-8 text"))?;
    let mut lines = text.lines();
    let Some(first) = lines.clone().next() else {
        return Err(Error::invalid("empty model file"));
    };
    if !first.starts_with(MAGIC) {
        // Legacy format: a bare SvmModel line file.
        return SvmModel::parse_lines(&mut text.lines()).map(ModelArtifact::Svm);
    }
    let header = next(&mut lines, "header")?;
    let ht: Vec<&str> = header.split_whitespace().collect();
    let (version, kind) = match ht.as_slice() {
        [m, v, k] if *m == MAGIC => (*v, *k),
        _ => return Err(Error::invalid(format!("bad header '{header}'"))),
    };
    if version != format!("v{VERSION}") {
        return Err(Error::invalid(format!(
            "unsupported model format version '{version}' (this build reads v{VERSION})"
        )));
    }
    match kind {
        "svm" => SvmModel::parse_lines(&mut lines).map(ModelArtifact::Svm),
        "mlsvm" => read_mlsvm_body(&mut lines).map(ModelArtifact::Mlsvm),
        "multiclass" => read_multiclass_body(&mut lines).map(ModelArtifact::Multiclass),
        other => Err(Error::invalid(format!("unknown model kind '{other}'"))),
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// A directory of named model files (`<name>.model`), the unit the
/// serving layer loads, lists and hot-reloads from.
pub struct Registry {
    dir: PathBuf,
    /// Fault-injection plan for the load path (disarmed by default; see
    /// [`crate::serve::faults`]).
    faults: Arc<FaultPlan>,
    /// Archived versions kept per model name (older ones are pruned on
    /// save/rollback).
    keep_versions: usize,
}

fn validate_name(name: &str) -> Result<()> {
    let ok = !name.is_empty()
        && !name.starts_with('.')
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'));
    if ok {
        Ok(())
    } else {
        Err(Error::invalid(format!(
            "bad model name '{name}' (use letters, digits, '-', '_', '.')"
        )))
    }
}

impl Registry {
    /// Open (creating if needed) a registry directory.
    pub fn open(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        Ok(Registry {
            dir,
            faults: FaultPlan::disarmed(),
            keep_versions: DEFAULT_KEEP_VERSIONS,
        })
    }

    /// Change how many archived versions each save keeps per name.
    pub fn set_keep_versions(&mut self, n: usize) {
        self.keep_versions = n;
    }

    /// Arm a fault plan on this registry's load path (chaos tests and
    /// the hidden `mlsvm serve --fault-plan` flag).
    pub fn set_faults(&mut self, faults: Arc<FaultPlan>) {
        self.faults = faults;
    }

    /// The backing directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// File path a model name maps to.
    pub fn path_of(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.{EXTENSION}"))
    }

    /// Save under `name`. [`save_artifact`] writes through a uniquely-
    /// named temp file in the registry directory, fsyncs and renames, so
    /// neither a concurrent `load`/reload, a racing save of the same
    /// name, nor a crash mid-save ever exposes a half-written model.
    ///
    /// Overwriting an existing name first archives the displaced
    /// artifact as the next dot-prefixed version file (see
    /// [`Registry::history`]), so the previous model stays reachable for
    /// [`Registry::rollback`]; versions beyond the keep limit are pruned
    /// afterwards. The current artifact is *copied* into the archive
    /// slot before the new one renames over it, so a crash at any point
    /// leaves `name` serving either the old or the new model — never
    /// neither.
    pub fn save(&self, name: &str, artifact: &ModelArtifact) -> Result<PathBuf> {
        validate_name(name)?;
        let path = self.path_of(name);
        if path.exists() {
            self.archive_current(name, &path)?;
        }
        save_artifact(&path, artifact)?;
        self.prune_versions(name)?;
        Ok(path)
    }

    /// Archive file a version of `name` maps to.
    fn version_path(&self, name: &str, version: u64) -> PathBuf {
        self.dir.join(format!(".{name}.{version}.{EXTENSION}"))
    }

    /// Copy the bytes at `current` into the next archive slot for
    /// `name`, crash-safely (temp + fsync + rename; `current` itself is
    /// untouched). Returns the archived version number.
    fn archive_current(&self, name: &str, current: &Path) -> Result<u64> {
        let next = self.history(name)?.last().map_or(0, |v| v.version) + 1;
        let bytes = std::fs::read(current)?;
        write_atomic(&self.version_path(name, next), |w| {
            w.write_all(&bytes)?;
            Ok(())
        })?;
        Ok(next)
    }

    /// Delete archived versions of `name` beyond the keep limit
    /// (oldest first).
    fn prune_versions(&self, name: &str) -> Result<()> {
        let vs = self.history(name)?;
        if vs.len() > self.keep_versions {
            for v in &vs[..vs.len() - self.keep_versions] {
                let _ = std::fs::remove_file(&v.path);
            }
        }
        Ok(())
    }

    /// Archived versions of `name`, oldest first (empty when the name
    /// was never overwritten). The *current* artifact is not an entry —
    /// it lives at [`Registry::path_of`].
    pub fn history(&self, name: &str) -> Result<Vec<VersionEntry>> {
        validate_name(name)?;
        let prefix = format!(".{name}.");
        let suffix = format!(".{EXTENSION}");
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let entry = entry?;
            let fname = entry.file_name();
            let Some(fname) = fname.to_str() else {
                continue;
            };
            let Some(mid) = fname
                .strip_prefix(&prefix)
                .and_then(|rest| rest.strip_suffix(&suffix))
            else {
                continue;
            };
            if mid.is_empty() || !mid.bytes().all(|b| b.is_ascii_digit()) {
                continue;
            }
            let Ok(version) = mid.parse::<u64>() else {
                continue;
            };
            let meta = entry.metadata()?;
            out.push(VersionEntry {
                version,
                bytes: meta.len(),
                modified: meta.modified().ok(),
                path: entry.path(),
            });
        }
        out.sort_by_key(|v| v.version);
        Ok(out)
    }

    /// Load one archived version of `name` (see [`Registry::history`]).
    pub fn load_version(&self, name: &str, version: u64) -> Result<ModelArtifact> {
        validate_name(name)?;
        let path = self.version_path(name, version);
        if !path.exists() {
            return Err(Error::invalid(format!(
                "model '{name}' has no archived version {version} in {}",
                self.dir.display()
            )));
        }
        load_artifact(path)
    }

    /// Roll `name` back to its newest archived version: the displaced
    /// current artifact is archived first (so a rollback is itself
    /// reversible and the bad model stays inspectable), then the
    /// archived file renames into place atomically. Returns the restored
    /// version number.
    pub fn rollback(&self, name: &str) -> Result<u64> {
        validate_name(name)?;
        let Some(prev) = self.history(name)?.pop() else {
            return Err(Error::invalid(format!(
                "model '{name}' has no archived version to roll back to"
            )));
        };
        let current = self.path_of(name);
        if current.exists() {
            self.archive_current(name, &current)?;
        }
        std::fs::rename(&prev.path, &current)?;
        self.prune_versions(name)?;
        Ok(prev.version)
    }

    /// Load the named model (versioned or legacy format).
    pub fn load(&self, name: &str) -> Result<ModelArtifact> {
        validate_name(name)?;
        let path = self.path_of(name);
        if !path.exists() {
            return Err(Error::invalid(format!(
                "model '{name}' not found in {}",
                self.dir.display()
            )));
        }
        match self.faults.registry_open() {
            LoadFault::None => load_artifact(path),
            LoadFault::Error => Err(Error::Serve(format!(
                "injected fault: registry read error loading '{name}'"
            ))),
            LoadFault::Truncate => {
                // Read the real bytes, then hand the parser only half of
                // them — the deterministic stand-in for a torn read or a
                // file corrupted by an interrupted external writer.
                let bytes = std::fs::read(&path)?;
                parse_artifact(&bytes[..bytes.len() / 2])
            }
        }
    }

    /// Sorted names of every model in the registry.
    pub fn list(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let entry = entry?;
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some(EXTENSION) {
                continue;
            }
            if let Some(stem) = path.file_stem().and_then(|s| s.to_str()) {
                if !stem.starts_with('.') {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }

    /// Rewrite every v1-text / legacy model in the registry as v2 binary
    /// (atomic per model, via [`Registry::save`]); already-binary models
    /// are left untouched. An unreadable model does **not** abort the
    /// run — it is reported with its error and the remaining models are
    /// still migrated, so a half-converted registry can never hide what
    /// happened. Returns one report per non-v2 model, in name order.
    pub fn migrate(&self) -> Result<Vec<MigrationReport>> {
        let mut out = Vec::new();
        for name in self.list()? {
            let path = self.path_of(&name);
            let from = detect_format(&path)?;
            if from == ModelFormat::V2Binary {
                continue;
            }
            let bytes_before = std::fs::metadata(&path)?.len();
            let result = load_artifact(&path).and_then(|artifact| self.save(&name, &artifact));
            let (bytes_after, error) = match result {
                Ok(_) => (std::fs::metadata(self.path_of(&name))?.len(), None),
                Err(e) => (bytes_before, Some(e.to_string())),
            };
            out.push(MigrationReport {
                name,
                from,
                bytes_before,
                bytes_after,
                error,
            });
        }
        Ok(out)
    }
}

/// One archived model version (see [`Registry::history`]).
#[derive(Clone, Debug)]
pub struct VersionEntry {
    /// Monotone version number (higher = newer).
    pub version: u64,
    /// Archived file size in bytes.
    pub bytes: u64,
    /// When the archive file was written (filesystem mtime), when the
    /// platform reports one.
    pub modified: Option<std::time::SystemTime>,
    /// The archive file itself (dot-prefixed, invisible to `list`).
    pub path: PathBuf,
}

/// One non-v2 model visited by [`Registry::migrate`].
#[derive(Clone, Debug)]
pub struct MigrationReport {
    /// Registry model name.
    pub name: String,
    /// Format the file was in before migration.
    pub from: ModelFormat,
    /// File size before (bytes).
    pub bytes_before: u64,
    /// File size after (bytes; unchanged when the migration failed).
    pub bytes_after: u64,
    /// Why this model could not be migrated (None = rewritten as v2).
    pub error: Option<String>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;
    use crate::svm::kernel::KernelKind;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mlsvm_registry_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// A tiny hand-built model with awkward float values (exercises the
    /// shortest-round-trip formatting).
    fn tiny_svm(gamma: f64) -> SvmModel {
        SvmModel {
            sv: Matrix::from_vec(2, 3, vec![0.1, -2.5, 3.75, 1.0 / 3.0, 0.0, -7.25]).unwrap(),
            sv_coef: vec![0.123456789012345, -2.0 / 3.0],
            rho: -0.037,
            kernel: KernelKind::Rbf { gamma },
            sv_indices: Vec::new(),
            sv_labels: vec![1, -1],
        }
    }

    fn tiny_mlsvm(gamma: f64) -> MlsvmModel {
        MlsvmModel {
            model: tiny_svm(gamma),
            params: SvmParams {
                c_pos: 4.2,
                c_neg: 0.7,
                kernel: KernelKind::Rbf { gamma },
                eps: 1e-3,
                max_iter: 12345,
                cache_bytes: 1 << 20,
                shrinking: true,
            },
            level_stats: vec![
                LevelStat {
                    levels: (2, 3),
                    train_size: 100,
                    n_sv: 17,
                    ud_used: true,
                    seconds: 0.125,
                    ud_seconds: 0.0625,
                    cv_gmean: Some(0.913),
                    solver: TrainStats {
                        iterations: 321,
                        gap: 9.5e-4,
                        cache_hits: 10,
                        cache_misses: 3,
                        warm_started: false,
                    },
                },
                LevelStat {
                    levels: (1, 2),
                    train_size: 250,
                    n_sv: 31,
                    ud_used: false,
                    seconds: 0.5,
                    ud_seconds: 0.0,
                    cv_gmean: None,
                    solver: TrainStats {
                        iterations: 77,
                        gap: 1e-4,
                        cache_hits: 40,
                        cache_misses: 2,
                        warm_started: true,
                    },
                },
            ],
            depths: (3, 4),
        }
    }

    fn probes() -> Vec<Vec<f32>> {
        vec![
            vec![0.0, 0.0, 0.0],
            vec![1.5, -0.25, 0.875],
            vec![-3.0, 2.0, 0.1],
        ]
    }

    #[test]
    fn svm_round_trip_is_bit_exact() {
        let dir = tmp_dir("svm_rt");
        let m = tiny_svm(0.3);
        let path = dir.join("m.model");
        save_artifact(&path, &ModelArtifact::Svm(m.clone())).unwrap();
        let ModelArtifact::Svm(back) = load_artifact(&path).unwrap() else {
            panic!("kind must round-trip")
        };
        for x in probes() {
            assert_eq!(m.decision(&x), back.decision(&x), "bit-for-bit decisions");
        }
        assert_eq!(m.sv_labels, back.sv_labels);
    }

    #[test]
    fn mlsvm_round_trip_preserves_model_and_metadata() {
        let dir = tmp_dir("mlsvm_rt");
        let m = tiny_mlsvm(0.45);
        let path = dir.join("m.model");
        save_artifact(&path, &ModelArtifact::Mlsvm(m.clone())).unwrap();
        let ModelArtifact::Mlsvm(back) = load_artifact(&path).unwrap() else {
            panic!("kind must round-trip")
        };
        for x in probes() {
            assert_eq!(m.model.decision(&x), back.model.decision(&x));
        }
        assert_eq!(back.depths, (3, 4));
        assert_eq!(back.level_stats.len(), 2);
        assert_eq!(back.level_stats[0].levels, (2, 3));
        assert_eq!(back.level_stats[0].cv_gmean, Some(0.913));
        assert_eq!(back.level_stats[0].ud_seconds, 0.0625);
        assert_eq!(back.level_stats[1].cv_gmean, None);
        assert_eq!(back.level_stats[1].ud_seconds, 0.0);
        assert!(back.level_stats[1].solver.warm_started);
        assert_eq!(back.level_stats[1].solver.cache_hits, 40);
        assert_eq!(back.params.c_pos, 4.2);
        assert_eq!(back.params.max_iter, 12345);
        assert_eq!(back.params.kernel, m.model.kernel);
    }

    #[test]
    fn multiclass_round_trip_keeps_failed_jobs() {
        let dir = tmp_dir("mc_rt");
        let mc = MulticlassModel {
            jobs: vec![
                ClassJob {
                    class_id: 0,
                    model: Some(tiny_mlsvm(0.2)),
                    error: None,
                    seconds: 1.5,
                    sizes: (40, 60),
                },
                ClassJob {
                    class_id: 7,
                    model: None,
                    error: Some("degenerate training set: class vanished\nat level 2".into()),
                    seconds: 0.01,
                    sizes: (0, 100),
                },
                ClassJob {
                    class_id: 2,
                    model: Some(tiny_mlsvm(1.7)),
                    error: None,
                    seconds: 2.25,
                    sizes: (55, 45),
                },
            ],
        };
        let path = dir.join("mc.model");
        save_artifact_v1(&path, &ModelArtifact::Multiclass(mc.clone())).unwrap();
        let ModelArtifact::Multiclass(back) = load_artifact(&path).unwrap() else {
            panic!("kind must round-trip")
        };
        assert_eq!(back.jobs.len(), 3);
        for x in probes() {
            assert_eq!(mc.predict(&x), back.predict(&x), "argmax preserved");
        }
        assert!(back.jobs[1].model.is_none());
        let msg = back.jobs[1].error.as_deref().unwrap();
        assert!(msg.contains("class vanished"), "{msg}");
        assert!(!msg.contains('\n'), "newlines must be flattened");
        assert_eq!(back.jobs[2].sizes, (55, 45));
    }

    #[test]
    fn empty_failure_messages_stay_loadable() {
        // A job that failed with an empty/whitespace message must still
        // produce a file the reader accepts.
        let dir = tmp_dir("empty_err");
        let mc = MulticlassModel {
            jobs: vec![ClassJob {
                class_id: 3,
                model: None,
                error: Some("\n ".into()),
                seconds: 0.0,
                sizes: (0, 10),
            }],
        };
        let path = dir.join("e.model");
        save_artifact_v1(&path, &ModelArtifact::Multiclass(mc)).unwrap();
        let ModelArtifact::Multiclass(back) = load_artifact(&path).unwrap() else {
            panic!("kind must round-trip")
        };
        assert_eq!(back.jobs[0].error.as_deref(), Some("unknown failure"));
    }

    #[test]
    fn level_lines_without_udsecs_still_load() {
        // Files written before the `udsecs` field existed must keep
        // loading, with the new field defaulting to 0.
        let dir = tmp_dir("pre_udsecs");
        let m = tiny_mlsvm(0.45);
        let path = dir.join("m.model");
        save_artifact_v1(&path, &ModelArtifact::Mlsvm(m.clone())).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let stripped: String = text
            .lines()
            .map(|l| {
                let l = match l.find(" udsecs ") {
                    Some(cut) if l.starts_with("level ") => &l[..cut],
                    _ => l,
                };
                format!("{l}\n")
            })
            .collect();
        std::fs::write(&path, stripped).unwrap();
        let ModelArtifact::Mlsvm(back) = load_artifact(&path).unwrap() else {
            panic!("kind must round-trip")
        };
        assert_eq!(back.level_stats.len(), 2);
        assert!(back.level_stats.iter().all(|s| s.ud_seconds == 0.0));
        for x in probes() {
            assert_eq!(m.model.decision(&x), back.model.decision(&x));
        }
    }

    #[test]
    fn legacy_line_files_still_load() {
        let dir = tmp_dir("legacy");
        let m = tiny_svm(0.9);
        let path = dir.join("old.model");
        m.save(&path).unwrap(); // the pre-registry line protocol
        let ModelArtifact::Svm(back) = load_artifact(&path).unwrap() else {
            panic!("legacy files load as bare SVMs")
        };
        for x in probes() {
            assert_eq!(m.decision(&x), back.decision(&x));
        }
    }

    #[test]
    fn garbage_truncation_and_bad_versions_are_rejected() {
        let dir = tmp_dir("reject");
        let garbage = dir.join("g.model");
        std::fs::write(&garbage, "not a model at all\n").unwrap();
        assert!(load_artifact(&garbage).is_err());

        let empty = dir.join("e.model");
        std::fs::write(&empty, "").unwrap();
        assert!(load_artifact(&empty).is_err());

        // Truncate a valid v1-text mlsvm file in the middle of the SV
        // block (binary truncation is covered in `serve::binary` tests).
        let full = dir.join("full.model");
        save_artifact_v1(&full, &ModelArtifact::Mlsvm(tiny_mlsvm(0.5))).unwrap();
        let text = std::fs::read_to_string(&full).unwrap();
        let cut: Vec<&str> = text.lines().collect();
        let truncated = cut[..cut.len() - 1].join("\n");
        let tpath = dir.join("t.model");
        std::fs::write(&tpath, truncated).unwrap();
        assert!(load_artifact(&tpath).is_err(), "truncated file must fail");

        let future = dir.join("v9.model");
        std::fs::write(&future, "mlsvm-model v9 svm\nkernel linear\n").unwrap();
        let err = load_artifact(&future).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn registry_save_load_list() {
        let dir = tmp_dir("reg");
        let reg = Registry::open(dir.join("models")).unwrap();
        assert!(reg.list().unwrap().is_empty());
        reg.save("alpha", &ModelArtifact::Svm(tiny_svm(0.1))).unwrap();
        reg.save("beta-v2", &ModelArtifact::Mlsvm(tiny_mlsvm(0.2)))
            .unwrap();
        assert_eq!(reg.list().unwrap(), vec!["alpha", "beta-v2"]);
        assert!(matches!(
            reg.load("alpha").unwrap(),
            ModelArtifact::Svm(_)
        ));
        assert!(matches!(
            reg.load("beta-v2").unwrap(),
            ModelArtifact::Mlsvm(_)
        ));
        assert!(reg.load("missing").is_err());
        assert!(reg.save("../evil", &ModelArtifact::Svm(tiny_svm(0.1))).is_err());
        assert!(reg.save("", &ModelArtifact::Svm(tiny_svm(0.1))).is_err());
    }

    #[test]
    fn interrupted_save_leaves_old_artifact_intact() {
        let dir = tmp_dir("torn");
        let reg = Registry::open(dir.join("models")).unwrap();
        reg.save("m", &ModelArtifact::Svm(tiny_svm(0.1))).unwrap();
        let before = std::fs::read(reg.path_of("m")).unwrap();

        // A successful save publishes atomically: no temp litter remains.
        let leftovers = |reg: &Registry| -> Vec<String> {
            std::fs::read_dir(reg.dir())
                .unwrap()
                .filter_map(|e| e.unwrap().file_name().into_string().ok())
                .filter(|n| n.ends_with(".tmp"))
                .collect()
        };
        assert!(leftovers(&reg).is_empty(), "{:?}", leftovers(&reg));

        // A writer that dies mid-save leaves only its dot-prefixed temp
        // behind — the published `m.model` is never half-written.
        let litter = reg.dir().join(".m.model.crashed-writer.tmp");
        std::fs::write(&litter, &before[..before.len() / 2]).unwrap();
        assert_eq!(reg.list().unwrap(), vec!["m"], "temp litter is invisible");
        assert!(matches!(reg.load("m").unwrap(), ModelArtifact::Svm(_)));
        assert_eq!(
            std::fs::read(reg.path_of("m")).unwrap(),
            before,
            "old artifact bytes survive an interrupted save"
        );

        // A save whose write fails (unreachable directory) must not
        // disturb the existing artifact either.
        assert!(save_artifact(
            dir.join("models/no-such-subdir/m.model"),
            &ModelArtifact::Svm(tiny_svm(0.2))
        )
        .is_err());
        assert_eq!(std::fs::read(reg.path_of("m")).unwrap(), before);

        // And the next real save replaces the artifact completely.
        reg.save("m", &ModelArtifact::Mlsvm(tiny_mlsvm(0.3))).unwrap();
        assert!(matches!(reg.load("m").unwrap(), ModelArtifact::Mlsvm(_)));
        assert_eq!(leftovers(&reg).len(), 1, "only the planted litter remains");
    }

    #[test]
    fn fault_plan_injects_load_errors_and_truncations() {
        let dir = tmp_dir("load_faults");
        let mut reg = Registry::open(dir.join("models")).unwrap();
        reg.save("m", &ModelArtifact::Mlsvm(tiny_mlsvm(0.3))).unwrap();

        let plan = FaultPlan::disarmed();
        plan.fail_loads(1, 1);
        plan.truncate_load(2);
        reg.set_faults(Arc::clone(&plan));

        let err = reg.load("m").unwrap_err().to_string();
        assert!(err.contains("injected"), "{err}");
        assert!(reg.load("m").is_err(), "truncated bytes must fail to parse");
        assert!(
            matches!(reg.load("m").unwrap(), ModelArtifact::Mlsvm(_)),
            "plan exhausted: the real artifact loads untouched"
        );
        let c = plan.injected();
        assert_eq!((c.load_errors, c.load_truncations), (1, 1));
        assert_eq!(c.total(), 2);
    }

    #[test]
    fn overwriting_archives_and_rollback_restores_bit_exactly() {
        let dir = tmp_dir("versions");
        let reg = Registry::open(dir.join("models")).unwrap();
        let (a, b) = (tiny_svm(0.1), tiny_svm(0.9));
        reg.save("m", &ModelArtifact::Svm(a.clone())).unwrap();
        assert!(reg.history("m").unwrap().is_empty(), "first save: no archive");
        let a_bytes = std::fs::read(reg.path_of("m")).unwrap();

        reg.save("m", &ModelArtifact::Svm(b.clone())).unwrap();
        let hist = reg.history("m").unwrap();
        assert_eq!(hist.len(), 1);
        assert_eq!(hist[0].version, 1);
        assert_eq!(hist[0].bytes, a_bytes.len() as u64);
        // Archives are dot-files: invisible to list(), reachable by version.
        assert_eq!(reg.list().unwrap(), vec!["m"]);
        let ModelArtifact::Svm(archived) = reg.load_version("m", 1).unwrap() else {
            panic!("kind preserved");
        };
        for x in probes() {
            assert_eq!(archived.decision(&x), a.decision(&x));
        }
        assert!(reg.load_version("m", 9).is_err());

        // Rollback: the displaced current is archived, v1 restores.
        assert_eq!(reg.rollback("m").unwrap(), 1);
        assert_eq!(
            std::fs::read(reg.path_of("m")).unwrap(),
            a_bytes,
            "rollback restores the archived bytes exactly"
        );
        let hist = reg.history("m").unwrap();
        assert_eq!(
            hist.iter().map(|v| v.version).collect::<Vec<_>>(),
            vec![2],
            "the rolled-back-from model stays reachable"
        );
        let ModelArtifact::Svm(bad) = reg.load_version("m", 2).unwrap() else {
            panic!("kind preserved");
        };
        for x in probes() {
            assert_eq!(bad.decision(&x), b.decision(&x));
        }
        // Rolling back again flips to the other model (the bad artifact
        // was archived, so a rollback is itself reversible).
        assert_eq!(reg.rollback("m").unwrap(), 2);
        let ModelArtifact::Svm(now) = reg.load("m").unwrap() else {
            panic!("kind preserved");
        };
        for x in probes() {
            assert_eq!(now.decision(&x), b.decision(&x));
        }
        // A name that was never overwritten has nothing to restore.
        reg.save("fresh", &ModelArtifact::Svm(tiny_svm(0.5))).unwrap();
        assert!(reg.rollback("fresh").is_err());
    }

    #[test]
    fn version_pruning_keeps_last_n() {
        let dir = tmp_dir("version_prune");
        let mut reg = Registry::open(dir.join("models")).unwrap();
        reg.set_keep_versions(2);
        for g in [1, 2, 3, 4, 5] {
            reg.save("m", &ModelArtifact::Svm(tiny_svm(g as f64 * 0.1)))
                .unwrap();
        }
        let hist = reg.history("m").unwrap();
        assert_eq!(
            hist.iter().map(|v| v.version).collect::<Vec<_>>(),
            vec![3, 4],
            "only the newest 2 archives survive"
        );
        assert!(hist.iter().all(|v| v.modified.is_some()));
        // Dotted model names never collide with version files.
        reg.save("m.2", &ModelArtifact::Svm(tiny_svm(0.7))).unwrap();
        reg.save("m.2", &ModelArtifact::Svm(tiny_svm(0.8))).unwrap();
        assert_eq!(reg.history("m.2").unwrap().len(), 1);
        assert_eq!(
            reg.history("m").unwrap().len(),
            2,
            "archives of 'm.2' are not versions of 'm'"
        );
    }

    #[test]
    fn registry_saves_are_v2_binary() {
        let dir = tmp_dir("reg_v2");
        let reg = Registry::open(dir.join("models")).unwrap();
        let path = reg.save("m", &ModelArtifact::Mlsvm(tiny_mlsvm(0.3))).unwrap();
        assert_eq!(detect_format(&path).unwrap(), ModelFormat::V2Binary);
    }

    #[test]
    fn v1_text_loads_bit_exactly_through_the_sniffing_reader() {
        let dir = tmp_dir("v1_compat");
        let m = tiny_mlsvm(0.45);
        let v1 = dir.join("v1.model");
        let v2 = dir.join("v2.model");
        save_artifact_v1(&v1, &ModelArtifact::Mlsvm(m.clone())).unwrap();
        save_artifact(&v2, &ModelArtifact::Mlsvm(m.clone())).unwrap();
        assert_eq!(detect_format(&v1).unwrap(), ModelFormat::V1Text);
        assert_eq!(detect_format(&v2).unwrap(), ModelFormat::V2Binary);
        let ModelArtifact::Mlsvm(from_v1) = load_artifact(&v1).unwrap() else {
            panic!("kind must round-trip");
        };
        let ModelArtifact::Mlsvm(from_v2) = load_artifact(&v2).unwrap() else {
            panic!("kind must round-trip");
        };
        // Both paths must agree with the original bit for bit.
        for x in probes() {
            let want = m.model.decision(&x);
            assert_eq!(from_v1.model.decision(&x), want, "v1 path");
            assert_eq!(from_v2.model.decision(&x), want, "v2 path");
        }
        assert_eq!(from_v1.depths, from_v2.depths);
        assert_eq!(from_v1.level_stats.len(), from_v2.level_stats.len());
    }

    #[test]
    fn migrate_rewrites_text_and_legacy_models_to_binary() {
        let dir = tmp_dir("migrate");
        let reg = Registry::open(dir.join("models")).unwrap();
        // One of each format: v1 text, legacy line file, already-v2.
        save_artifact_v1(&reg.path_of("old-text"), &ModelArtifact::Mlsvm(tiny_mlsvm(0.2)))
            .unwrap();
        tiny_svm(0.9).save(reg.path_of("old-lines")).unwrap();
        reg.save("already-v2", &ModelArtifact::Svm(tiny_svm(0.4))).unwrap();
        let text_decisions: Vec<f64> = probes()
            .iter()
            .map(|x| tiny_mlsvm(0.2).model.decision(x))
            .collect();

        let reports = reg.migrate().unwrap();
        assert_eq!(reports.len(), 2, "already-v2 must be skipped");
        let names: Vec<&str> = reports.iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, vec!["old-lines", "old-text"]);
        assert_eq!(reports[0].from, ModelFormat::LegacyLines);
        assert_eq!(reports[1].from, ModelFormat::V1Text);
        assert!(reports.iter().all(|r| r.error.is_none()));
        for name in ["old-text", "old-lines", "already-v2"] {
            assert_eq!(
                detect_format(reg.path_of(name)).unwrap(),
                ModelFormat::V2Binary,
                "{name}"
            );
        }
        // Decisions survive the migration bit for bit.
        let ModelArtifact::Mlsvm(back) = reg.load("old-text").unwrap() else {
            panic!("kind preserved");
        };
        for (x, want) in probes().iter().zip(text_decisions) {
            assert_eq!(back.model.decision(x), want);
        }
        // Migrating again is a no-op.
        assert!(reg.migrate().unwrap().is_empty());
    }

    #[test]
    fn migrate_survives_an_unreadable_model() {
        // One corrupt file must not abort the run or hide the models that
        // did convert.
        let dir = tmp_dir("migrate_bad");
        let reg = Registry::open(dir.join("models")).unwrap();
        save_artifact_v1(reg.path_of("good"), &ModelArtifact::Svm(tiny_svm(0.3))).unwrap();
        std::fs::write(reg.path_of("broken"), "kernel rbf not-a-number\n").unwrap();
        let reports = reg.migrate().unwrap();
        assert_eq!(reports.len(), 2);
        let good = reports.iter().find(|r| r.name == "good").unwrap();
        assert!(good.error.is_none());
        assert_eq!(detect_format(reg.path_of("good")).unwrap(), ModelFormat::V2Binary);
        let broken = reports.iter().find(|r| r.name == "broken").unwrap();
        assert!(broken.error.is_some(), "corrupt model must be reported");
        // The corrupt file is left untouched for inspection.
        assert_eq!(
            detect_format(reg.path_of("broken")).unwrap(),
            ModelFormat::LegacyLines
        );
    }
}
