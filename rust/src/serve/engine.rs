//! The concurrent dynamic-batching decision engine.
//!
//! This generalizes the single-threaded [`crate::coordinator::Router`]
//! into a serving-grade component (the vLLM-style continuous batcher,
//! scaled to SVM decision functions):
//!
//! * [`FlushPolicy`] — when a queue is worth flushing: the batch filled to
//!   `max_batch` (size trigger) or the oldest request has waited
//!   `max_wait` (deadline trigger, bounds tail latency);
//! * [`BatchQueue`] — the single-threaded batching core (pending queue,
//!   deadline clock, ticket → result bookkeeping, [`BatchStats`]). The
//!   `Router` is a thin wrapper over this plus an execution backend;
//! * [`ModelSlot`] — the hot-swappable model handle: an `Arc` swap behind
//!   an `RwLock`. The engine holds one `Arc<ModelSlot>` and the
//!   [`crate::serve::manager::EngineManager`] that spawned it holds
//!   another, so either side can reload the model without the engine
//!   knowing where models come from (the engine carries no embedded
//!   single-model assumption — it evaluates whatever the slot holds);
//! * [`Engine`] — the threaded generalization: a `Mutex`+`Condvar`
//!   bounded request queue (backpressure: `submit` blocks while the queue
//!   is at capacity), worker threads that flush due batches through a
//!   tiled batched kernel evaluation (the `fill_rows_batch` style: norms
//!   identity + hoisted transcendental pass), and per-class argmax for
//!   one-vs-rest ensembles.
//!
//! Every request is answered through a one-shot [`std::sync::mpsc`]
//! channel, so callers can block (`Ticket::wait`), poll with a timeout,
//! or fan out thousands of tickets and collect later.

use crate::coordinator::jobs::MulticlassModel;
use crate::data::matrix::{dot, Matrix};
use crate::data::simd;
use crate::error::{Error, Result};
use crate::mlsvm::ensemble;
use crate::runtime::{PjrtDecision, Runtime};
use crate::serve::faults::FaultPlan;
use crate::serve::registry::ModelArtifact;
use crate::serve::stats::{BatchStats, EngineStats, StatsSnapshot};
use crate::svm::kernel::{KernelKind, KERNEL_TILE};
use crate::svm::model::SvmModel;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock};
use std::time::{Duration, Instant};

/// Re-enter limit for a worker whose loop itself panicked (outside the
/// per-batch `catch_unwind`): after this many re-entries the worker
/// stays down rather than spinning on a deterministic crash.
const WORKER_RESPAWN_CAP: usize = 8;

/// Acquire a mutex, recovering from poisoning. A poisoned lock here
/// means some thread panicked while holding it; the queue state it
/// protects is a plain `VecDeque` + flags that stay structurally valid
/// at every await point, and the panic itself is surfaced through the
/// ticket/stats path — so subsequent requests must keep working instead
/// of cascading the abort.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------------
// Scoring mode (f32 default, opt-in i8 quantized)
// ---------------------------------------------------------------------------

/// Numeric mode of the batch scorer. The default [`ScoreMode::F32`] path
/// is bit-identical to the classic per-query tiled scorer; the opt-in
/// [`ScoreMode::QuantizedI8`] path trades dot-product precision for
/// throughput (i8 panels, i32 accumulation) and is surfaced with a
/// measured decision-agreement in `BENCH_serve.json`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScoreMode {
    /// Full-precision f32 dot products (the determinism-contract path).
    F32 = 0,
    /// i8 support-vector panels with per-row scales and i32 accumulation.
    QuantizedI8 = 1,
}

impl ScoreMode {
    /// Stable short name for stats/bench JSON ("f32" / "i8").
    pub fn name(self) -> &'static str {
        match self {
            ScoreMode::F32 => "f32",
            ScoreMode::QuantizedI8 => "i8",
        }
    }
}

/// Minimum fraction of queries on which quantized decisions must agree
/// with the f32 scorer (same predicted label). Shared by the property
/// test, the serve bench, and `ci/check_bench.py --serve`.
pub const QUANT_AGREEMENT_FLOOR: f64 = 0.95;

/// Process-wide scoring mode, set once by `mlsvm serve --quantize i8`
/// before any model loads. [`ArtifactScorer::new`] reads it so the whole
/// serving stack (manager, canaries, reloads) inherits the flag without
/// threading it through every constructor.
static SCORE_MODE: AtomicU8 = AtomicU8::new(0);

/// Set the process-wide scoring mode (CLI startup path).
pub fn set_score_mode(mode: ScoreMode) {
    SCORE_MODE.store(mode as u8, Ordering::Relaxed);
}

/// The process-wide scoring mode in force.
pub fn score_mode() -> ScoreMode {
    if SCORE_MODE.load(Ordering::Relaxed) == ScoreMode::QuantizedI8 as u8 {
        ScoreMode::QuantizedI8
    } else {
        ScoreMode::F32
    }
}

// ---------------------------------------------------------------------------
// Flush policy (shared by BatchQueue and the threaded Engine)
// ---------------------------------------------------------------------------

/// Why a batch is due.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    /// The queue reached `max_batch`.
    Size,
    /// The oldest pending request waited `max_wait`.
    Deadline,
}

/// Size/deadline flush triggers.
#[derive(Clone, Copy, Debug)]
pub struct FlushPolicy {
    /// Flush when this many requests are pending.
    pub max_batch: usize,
    /// Flush a partial batch once the oldest request has waited this long.
    pub max_wait: Duration,
}

impl FlushPolicy {
    /// New policy (`max_batch` is clamped to ≥ 1).
    pub fn new(max_batch: usize, max_wait: Duration) -> FlushPolicy {
        FlushPolicy {
            max_batch: max_batch.max(1),
            max_wait,
        }
    }

    /// Whether a queue of `queued` requests whose oldest entry arrived at
    /// `oldest` should flush now, and why.
    pub fn due(&self, queued: usize, oldest: Option<Instant>) -> Option<FlushReason> {
        if queued == 0 {
            return None;
        }
        if queued >= self.max_batch {
            return Some(FlushReason::Size);
        }
        match oldest {
            Some(t0) if t0.elapsed() >= self.max_wait => Some(FlushReason::Deadline),
            _ => None,
        }
    }

    /// Time until the deadline trigger fires (None when nothing pends).
    pub fn time_left(&self, oldest: Option<Instant>) -> Option<Duration> {
        oldest.map(|t0| self.max_wait.saturating_sub(t0.elapsed()))
    }
}

// ---------------------------------------------------------------------------
// BatchQueue: the single-threaded batching core
// ---------------------------------------------------------------------------

/// Single-threaded batching core: accumulates submitted feature vectors,
/// tracks the deadline clock, assembles due batches into a [`Matrix`],
/// and maps tickets to completed decision values.
///
/// [`crate::coordinator::Router`] drives this from its event loop; the
/// threaded [`Engine`] implements the same policy with its own
/// channel-based bookkeeping.
pub struct BatchQueue {
    policy: FlushPolicy,
    pending: Vec<(u64, Vec<f32>)>,
    oldest: Option<Instant>,
    results: HashMap<u64, f64>,
    next_id: u64,
    stats: BatchStats,
}

impl BatchQueue {
    /// Empty queue under the given flush policy.
    pub fn new(max_batch: usize, max_wait: Duration) -> BatchQueue {
        BatchQueue {
            policy: FlushPolicy::new(max_batch, max_wait),
            pending: Vec::new(),
            oldest: None,
            results: HashMap::new(),
            next_id: 0,
            stats: BatchStats::default(),
        }
    }

    /// Enqueue a request; returns its ticket.
    pub fn submit(&mut self, x: &[f32]) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        if self.pending.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.pending.push((id, x.to_vec()));
        self.stats.requests += 1;
        id
    }

    /// Number of queued requests.
    pub fn queued(&self) -> usize {
        self.pending.len()
    }

    /// The flush policy in force.
    pub fn policy(&self) -> FlushPolicy {
        self.policy
    }

    /// Whether (and why) a flush is due now.
    pub fn due(&self) -> Option<FlushReason> {
        self.policy.due(self.pending.len(), self.oldest)
    }

    /// Counters so far.
    pub fn stats(&self) -> &BatchStats {
        &self.stats
    }

    /// Pop up to `max_batch` requests as `(tickets, query matrix)`,
    /// recording the batch in the stats (`deadline` marks why it ran).
    /// Returns `None` when nothing is pending.
    pub fn next_batch(&mut self, deadline: bool) -> Option<(Vec<u64>, Matrix)> {
        if self.pending.is_empty() {
            return None;
        }
        let take = self.pending.len().min(self.policy.max_batch);
        let batch: Vec<(u64, Vec<f32>)> = self.pending.drain(..take).collect();
        self.oldest = if self.pending.is_empty() {
            None
        } else {
            Some(Instant::now())
        };
        let dim = batch[0].1.len();
        let mut m = Matrix::zeros(batch.len(), dim);
        let mut ids = Vec::with_capacity(batch.len());
        for (r, (id, x)) in batch.iter().enumerate() {
            m.row_mut(r).copy_from_slice(x);
            ids.push(*id);
        }
        self.stats.batches += 1;
        self.stats.slots += self.policy.max_batch as u64;
        if deadline {
            self.stats.deadline_flushes += 1;
        }
        Some((ids, m))
    }

    /// Record the decision values of a completed batch.
    pub fn complete(&mut self, ids: &[u64], vals: Vec<f64>) {
        for (id, v) in ids.iter().zip(vals) {
            self.results.insert(*id, v);
        }
    }

    /// Collect a finished result.
    pub fn take(&mut self, id: u64) -> Option<f64> {
        self.results.remove(&id)
    }
}

// ---------------------------------------------------------------------------
// Decision scorers (batched kernel evaluation against the SV set)
// ---------------------------------------------------------------------------

/// One answered prediction.
#[derive(Clone, Debug, PartialEq)]
pub enum Decision {
    /// Binary model: decision value and its sign label.
    Binary {
        /// f(x) = Σ coef·K(sv, x) − ρ.
        value: f64,
        /// sign(f(x)) with ties → −1.
        label: i8,
    },
    /// One-vs-rest ensemble: winning class (argmax of decisions) and the
    /// per-class decision values.
    Multiclass {
        /// Winning class id (None when no class model is available).
        class: Option<u8>,
        /// (class id, decision value) per available class model.
        scores: Vec<(u8, f64)>,
    },
}

/// Decision-function evaluator over one binary [`SvmModel`], with
/// precomputed support-vector norms so each query costs one pass of dot
/// products plus a hoisted transcendental tile — the same structure as
/// [`crate::svm::kernel::RustRowBackend::fill_rows_batch`], applied to
/// query-vs-SV rows instead of train-vs-train rows.
pub struct BinaryScorer {
    model: SvmModel,
    sv_norms: Vec<f64>,
    layout: ScorerLayout,
}

/// Blocked support-vector layout, built once at model load. The
/// row-major SV matrix already stores each [`KERNEL_TILE`] tile of rows
/// as one contiguous panel, so the f32 layout is the panel schedule the
/// blocked batch scorer streams; in [`ScoreMode::QuantizedI8`] it
/// additionally holds the i8 panel with per-row scales. `build_ms` is
/// reported in `BENCH_serve.json` so model-load regressions show up.
pub struct ScorerLayout {
    quant: Option<QuantPanel>,
    build_ms: f64,
}

/// Quantized support vectors: i8 rows (same row-major shape as the f32
/// SV matrix) plus one f32 dequantization scale per row (max|row|/127).
struct QuantPanel {
    rows: Vec<i8>,
    scales: Vec<f32>,
}

impl QuantPanel {
    fn build(sv: &Matrix) -> QuantPanel {
        let (n, d) = (sv.rows(), sv.cols());
        let mut rows = vec![0i8; n * d];
        let mut scales = vec![0.0f32; n];
        for j in 0..n {
            let r = sv.row(j);
            let maxabs = r.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            if maxabs == 0.0 {
                continue; // all-zero row quantizes to zeros with scale 0
            }
            let scale = maxabs / 127.0;
            scales[j] = scale;
            for (q, &v) in rows[j * d..(j + 1) * d].iter_mut().zip(r) {
                *q = (v / scale).round().clamp(-127.0, 127.0) as i8;
            }
        }
        QuantPanel { rows, scales }
    }
}

/// Quantize one query against its own max-abs scale; returns the scale
/// (0.0 for an all-zero query, whose quantized form is all zeros).
fn quantize_query(x: &[f32], out: &mut [i8]) -> f32 {
    let maxabs = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if maxabs == 0.0 {
        out.fill(0);
        return 0.0;
    }
    let scale = maxabs / 127.0;
    for (q, &v) in out.iter_mut().zip(x) {
        *q = (v / scale).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

/// i8·i8 dot with i32 accumulation (products are ≤ 127², so dimensions
/// far beyond any SVM feature count fit without overflow).
fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    let mut acc = 0i32;
    for (&x, &y) in a.iter().zip(b) {
        acc += x as i32 * y as i32;
    }
    acc
}

impl BinaryScorer {
    /// Wrap a model in the default f32 mode (precomputes ‖sv‖²).
    pub fn new(model: SvmModel) -> BinaryScorer {
        BinaryScorer::with_mode(model, ScoreMode::F32)
    }

    /// Wrap a model, building the blocked scoring layout for `mode`.
    pub fn with_mode(model: SvmModel, mode: ScoreMode) -> BinaryScorer {
        let t = Instant::now();
        let sv_norms = model.sv.row_sqnorms();
        let quant = match mode {
            ScoreMode::F32 => None,
            ScoreMode::QuantizedI8 => Some(QuantPanel::build(&model.sv)),
        };
        let layout = ScorerLayout {
            quant,
            build_ms: t.elapsed().as_secs_f64() * 1e3,
        };
        BinaryScorer {
            model,
            sv_norms,
            layout,
        }
    }

    /// Feature dimensionality the model expects.
    pub fn dim(&self) -> usize {
        self.model.sv.cols()
    }

    /// The wrapped model.
    pub fn model(&self) -> &SvmModel {
        &self.model
    }

    /// The numeric mode this scorer was built for.
    pub fn mode(&self) -> ScoreMode {
        if self.layout.quant.is_some() {
            ScoreMode::QuantizedI8
        } else {
            ScoreMode::F32
        }
    }

    /// Milliseconds spent building the scoring layout (norms + panels).
    pub fn layout_build_ms(&self) -> f64 {
        self.layout.build_ms
    }

    /// Decision value for one query (tiled batched-kernel path; agrees
    /// with [`SvmModel::decision`] up to f32-dot rounding). In quantized
    /// mode this routes through the i8 panel so single-query and batch
    /// answers stay self-consistent.
    pub fn decide(&self, x: &[f32]) -> f64 {
        if self.layout.quant.is_some() {
            return self.decide_quant(x);
        }
        let m = &self.model;
        let nsv = m.n_sv();
        let mut s = -m.rho;
        match m.kernel {
            KernelKind::Rbf { gamma } => {
                let nq: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum();
                let mut d2 = [0.0f64; KERNEL_TILE];
                let mut t0 = 0usize;
                while t0 < nsv {
                    let t1 = (t0 + KERNEL_TILE).min(nsv);
                    // pass 1: squared distances via the norm identity
                    for j in t0..t1 {
                        d2[j - t0] =
                            (nq + self.sv_norms[j] - 2.0 * dot(m.sv.row(j), x) as f64).max(0.0);
                    }
                    // pass 2: hoisted exp + accumulate
                    for j in t0..t1 {
                        s += m.sv_coef[j] * (-gamma * d2[j - t0]).exp();
                    }
                    t0 = t1;
                }
            }
            KernelKind::Linear => {
                for j in 0..nsv {
                    s += m.sv_coef[j] * dot(m.sv.row(j), x) as f64;
                }
            }
            KernelKind::Poly {
                gamma,
                coef0,
                degree,
            } => {
                for j in 0..nsv {
                    s += m.sv_coef[j]
                        * (gamma * dot(m.sv.row(j), x) as f64 + coef0).powi(degree as i32);
                }
            }
        }
        s
    }

    /// Blocked batch scoring: tiles outer, queries inner, so each
    /// [`KERNEL_TILE`] panel of SV rows is streamed once per flush and
    /// stays cache-resident while every query in the batch scores
    /// against it. Per query the accumulation order (ascending `j`
    /// across ascending tiles) is exactly [`BinaryScorer::decide`]'s,
    /// so f32-mode results are bit-identical to the per-query scorer.
    pub fn decide_many(&self, xs: &Matrix, out: &mut [f64]) {
        debug_assert_eq!(out.len(), xs.rows());
        if self.layout.quant.is_some() {
            self.decide_many_quant(xs, out);
        } else {
            self.decide_many_f32(xs, out);
        }
    }

    fn decide_many_f32(&self, xs: &Matrix, out: &mut [f64]) {
        let m = &self.model;
        let nsv = m.n_sv();
        let d = m.sv.cols();
        let sv = m.sv.as_slice();
        out.fill(-m.rho);
        let qnorms: Vec<f64> = match m.kernel {
            KernelKind::Rbf { .. } => (0..xs.rows())
                .map(|q| xs.row(q).iter().map(|&v| (v as f64) * (v as f64)).sum())
                .collect(),
            _ => Vec::new(),
        };
        let mut dots = [0.0f32; KERNEL_TILE];
        let mut t0 = 0usize;
        while t0 < nsv {
            let t1 = (t0 + KERNEL_TILE).min(nsv);
            let panel = &sv[t0 * d..t1 * d];
            for q in 0..xs.rows() {
                let x = xs.row(q);
                simd::dot_rows(x, panel, d, &mut dots[..t1 - t0]);
                let mut s = out[q];
                match m.kernel {
                    KernelKind::Rbf { gamma } => {
                        let nq = qnorms[q];
                        for j in t0..t1 {
                            let d2 =
                                (nq + self.sv_norms[j] - 2.0 * dots[j - t0] as f64).max(0.0);
                            s += m.sv_coef[j] * (-gamma * d2).exp();
                        }
                    }
                    KernelKind::Linear => {
                        for j in t0..t1 {
                            s += m.sv_coef[j] * dots[j - t0] as f64;
                        }
                    }
                    KernelKind::Poly {
                        gamma,
                        coef0,
                        degree,
                    } => {
                        for j in t0..t1 {
                            s += m.sv_coef[j]
                                * (gamma * dots[j - t0] as f64 + coef0).powi(degree as i32);
                        }
                    }
                }
                out[q] = s;
            }
            t0 = t1;
        }
    }

    fn decide_quant(&self, x: &[f32]) -> f64 {
        let m = &self.model;
        let nsv = m.n_sv();
        let mut qx = vec![0i8; x.len()];
        let qscale = quantize_query(x, &mut qx);
        // The query norm stays exact (from the f32 query): quantization
        // only approximates the dot products.
        let nq: f64 = match m.kernel {
            KernelKind::Rbf { .. } => x.iter().map(|&v| (v as f64) * (v as f64)).sum(),
            _ => 0.0,
        };
        let mut s = -m.rho;
        let mut t0 = 0usize;
        while t0 < nsv {
            let t1 = (t0 + KERNEL_TILE).min(nsv);
            self.quant_tile(&qx, qscale, nq, t0, t1, &mut s);
            t0 = t1;
        }
        s
    }

    fn decide_many_quant(&self, xs: &Matrix, out: &mut [f64]) {
        let m = &self.model;
        let nsv = m.n_sv();
        let d = m.sv.cols();
        let n = xs.rows();
        // Quantize every query once up front (amortized over all tiles).
        let mut qxs = vec![0i8; n * d];
        let mut qscales = vec![0.0f32; n];
        for q in 0..n {
            qscales[q] = quantize_query(xs.row(q), &mut qxs[q * d..(q + 1) * d]);
        }
        let qnorms: Vec<f64> = match m.kernel {
            KernelKind::Rbf { .. } => (0..n)
                .map(|q| xs.row(q).iter().map(|&v| (v as f64) * (v as f64)).sum())
                .collect(),
            _ => vec![0.0; n],
        };
        out.fill(-m.rho);
        let mut t0 = 0usize;
        while t0 < nsv {
            let t1 = (t0 + KERNEL_TILE).min(nsv);
            for q in 0..n {
                self.quant_tile(
                    &qxs[q * d..(q + 1) * d],
                    qscales[q],
                    qnorms[q],
                    t0,
                    t1,
                    &mut out[q],
                );
            }
            t0 = t1;
        }
    }

    /// Accumulate one (query, SV-tile) block of the quantized decision
    /// sum. Shared by the single-query and batch paths so both produce
    /// identical values for the same query.
    fn quant_tile(&self, qx: &[i8], qscale: f32, nq: f64, t0: usize, t1: usize, s: &mut f64) {
        let qp = self.layout.quant.as_ref().expect("quantized layout");
        let m = &self.model;
        let d = m.sv.cols();
        match m.kernel {
            KernelKind::Rbf { gamma } => {
                for j in t0..t1 {
                    let dq =
                        dot_i8(qx, &qp.rows[j * d..(j + 1) * d]) as f32 * qp.scales[j] * qscale;
                    let d2 = (nq + self.sv_norms[j] - 2.0 * dq as f64).max(0.0);
                    *s += m.sv_coef[j] * (-gamma * d2).exp();
                }
            }
            KernelKind::Linear => {
                for j in t0..t1 {
                    let dq =
                        dot_i8(qx, &qp.rows[j * d..(j + 1) * d]) as f32 * qp.scales[j] * qscale;
                    *s += m.sv_coef[j] * dq as f64;
                }
            }
            KernelKind::Poly {
                gamma,
                coef0,
                degree,
            } => {
                for j in t0..t1 {
                    let dq =
                        dot_i8(qx, &qp.rows[j * d..(j + 1) * d]) as f32 * qp.scales[j] * qscale;
                    *s += m.sv_coef[j] * (gamma * dq as f64 + coef0).powi(degree as i32);
                }
            }
        }
    }
}

enum ScorerKind {
    Binary(BinaryScorer),
    /// (class id, scorer) per class that has a trained model.
    Multi(Vec<(u8, BinaryScorer)>),
    /// One scorer per voting member of a best-levels ensemble, in roster
    /// order. Decisions combine via [`ensemble::vote`], so the served
    /// answer is bit-identical to `EnsembleModel::predict_label`.
    Voting(Vec<BinaryScorer>),
}

/// Device-side scorer state: the PJRT runtime plus the compiled decision
/// executable for the loaded model. Mutex-guarded because runtime
/// execution needs `&mut` (buffer transfers are stateful).
struct DeviceState {
    rt: Runtime,
    dec: PjrtDecision,
}

/// Try to bring up the PJRT device path for a binary model. Present only
/// when a compiled decision artifact is loadable — real `pjrt` builds
/// with `$MLSVM_ARTIFACTS`/`./artifacts` populated; the stub runtime
/// always declines, which keeps default builds on the bit-exact rust
/// tiles.
fn attach_device(model: &SvmModel) -> Option<Mutex<DeviceState>> {
    let rt = Runtime::new(Runtime::default_dir()).ok()?;
    let dec = PjrtDecision::new(&rt, model).ok()?;
    Some(Mutex::new(DeviceState { rt, dec }))
}

/// Wrap a binary decision value with its sign label (ties → −1).
fn binary_decision(value: f64) -> Decision {
    Decision::Binary {
        value,
        label: if value > 0.0 { 1 } else { -1 },
    }
}

/// Argmax with first-best-wins ties, matching `MulticlassModel::predict`.
fn multiclass_decision(scores: Vec<(u8, f64)>) -> Decision {
    let mut best: Option<(u8, f64)> = None;
    for &(c, d) in &scores {
        if best.map(|(_, bd)| d > bd).unwrap_or(true) {
            best = Some((c, d));
        }
    }
    Decision::Multiclass {
        class: best.map(|(c, _)| c),
        scores,
    }
}

/// Prepared evaluator for any [`ModelArtifact`] kind.
pub struct ArtifactScorer {
    kind: ScorerKind,
    dim: usize,
    device: Option<Mutex<DeviceState>>,
    device_batches: AtomicU64,
}

impl ArtifactScorer {
    /// Prepare an artifact for serving (clones the finest models out of
    /// it; multilevel metadata stays behind). Scores in the process-wide
    /// [`score_mode`].
    pub fn new(artifact: &ModelArtifact) -> Result<ArtifactScorer> {
        ArtifactScorer::with_mode(artifact, score_mode())
    }

    /// Prepare an artifact for serving in an explicit [`ScoreMode`]
    /// (benches compare modes side by side within one process).
    pub fn with_mode(artifact: &ModelArtifact, mode: ScoreMode) -> Result<ArtifactScorer> {
        let kind = match artifact {
            ModelArtifact::Svm(m) => ScorerKind::Binary(BinaryScorer::with_mode(m.clone(), mode)),
            ModelArtifact::Mlsvm(m) => {
                ScorerKind::Binary(BinaryScorer::with_mode(m.model.clone(), mode))
            }
            ModelArtifact::Multiclass(mc) => {
                let scorers = multiclass_scorers(mc, mode);
                if scorers.is_empty() {
                    return Err(Error::Serve(
                        "multiclass artifact has no trained class models".into(),
                    ));
                }
                ScorerKind::Multi(scorers)
            }
            ModelArtifact::Ensemble(e) => {
                if e.members.is_empty() {
                    return Err(Error::Serve("ensemble artifact has no members".into()));
                }
                ScorerKind::Voting(
                    e.members
                        .iter()
                        .map(|m| BinaryScorer::with_mode(m.model.clone(), mode))
                        .collect(),
                )
            }
        };
        let dim = match &kind {
            ScorerKind::Binary(b) => b.dim(),
            ScorerKind::Multi(list) => {
                let d = list[0].1.dim();
                if list.iter().any(|(_, s)| s.dim() != d) {
                    return Err(Error::Serve(
                        "multiclass artifact mixes feature dimensionalities".into(),
                    ));
                }
                d
            }
            ScorerKind::Voting(list) => {
                let d = list[0].dim();
                if list.iter().any(|s| s.dim() != d) {
                    return Err(Error::Serve(
                        "ensemble artifact mixes feature dimensionalities".into(),
                    ));
                }
                d
            }
        };
        // The device decision path is f32-only and binary-only; quantized
        // and multiclass scoring always run the rust tiles.
        let device = match (&kind, mode) {
            (ScorerKind::Binary(b), ScoreMode::F32) => attach_device(b.model()),
            _ => None,
        };
        Ok(ArtifactScorer {
            kind,
            dim,
            device,
            device_batches: AtomicU64::new(0),
        })
    }

    /// Feature dimensionality queries must have.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// "binary", "multiclass" or "ensemble".
    pub fn kind_name(&self) -> &'static str {
        match self.kind {
            ScorerKind::Binary(_) => "binary",
            ScorerKind::Multi(_) => "multiclass",
            ScorerKind::Voting(_) => "ensemble",
        }
    }

    /// Resident memory the support-vector matrices pin, in bytes
    /// (SV count × dim × 4 per class model) — the dominant term of a
    /// loaded model's footprint, and the unit the manager's byte-budget
    /// capacity policy counts.
    pub fn resident_bytes(&self) -> u64 {
        let sv_bytes = |b: &BinaryScorer| {
            (b.model().sv.rows() as u64) * (b.model().sv.cols() as u64) * 4
        };
        match &self.kind {
            ScorerKind::Binary(b) => sv_bytes(b),
            ScorerKind::Multi(list) => list.iter().map(|(_, s)| sv_bytes(s)).sum(),
            ScorerKind::Voting(list) => list.iter().map(sv_bytes).sum(),
        }
    }

    /// Numeric mode the scorer was built for.
    pub fn mode(&self) -> ScoreMode {
        match &self.kind {
            ScorerKind::Binary(b) => b.mode(),
            ScorerKind::Multi(list) => list[0].1.mode(),
            ScorerKind::Voting(list) => list[0].mode(),
        }
    }

    /// Stable short name of the numeric mode ("f32" / "i8").
    pub fn mode_name(&self) -> &'static str {
        self.mode().name()
    }

    /// Total milliseconds spent building scoring layouts (summed over
    /// class models for multiclass artifacts).
    pub fn layout_build_ms(&self) -> f64 {
        match &self.kind {
            ScorerKind::Binary(b) => b.layout_build_ms(),
            ScorerKind::Multi(list) => list.iter().map(|(_, s)| s.layout_build_ms()).sum(),
            ScorerKind::Voting(list) => list.iter().map(|s| s.layout_build_ms()).sum(),
        }
    }

    /// Whether the PJRT device decision path is attached.
    pub fn device_active(&self) -> bool {
        self.device.is_some()
    }

    /// Batches answered by the device path so far.
    pub fn device_batches(&self) -> u64 {
        self.device_batches.load(Ordering::Relaxed)
    }

    /// Evaluate one query.
    pub fn decide(&self, x: &[f32]) -> Decision {
        match &self.kind {
            ScorerKind::Binary(b) => binary_decision(b.decide(x)),
            ScorerKind::Multi(list) => {
                let scores: Vec<(u8, f64)> =
                    list.iter().map(|(c, s)| (*c, s.decide(x))).collect();
                multiclass_decision(scores)
            }
            ScorerKind::Voting(list) => {
                let vals: Vec<f64> = list.iter().map(|s| s.decide(x)).collect();
                let (value, label) = ensemble::vote(&vals);
                Decision::Binary { value, label }
            }
        }
    }

    /// Evaluate every row of a query matrix — the engine-flush path.
    /// Binary models go through the device batch executable when one is
    /// attached, else the blocked rust tiles; multiclass runs the
    /// blocked tiles once per class and argmaxes per row. Values and
    /// ordering are identical to calling [`ArtifactScorer::decide`] per
    /// row (bit-identical in f32 mode without a device).
    pub fn decide_batch(&self, xs: &Matrix) -> Vec<Decision> {
        if let Some(vals) = self.device_batch(xs) {
            return vals.into_iter().map(binary_decision).collect();
        }
        match &self.kind {
            ScorerKind::Binary(b) => {
                let mut vals = vec![0.0f64; xs.rows()];
                b.decide_many(xs, &mut vals);
                vals.into_iter().map(binary_decision).collect()
            }
            ScorerKind::Multi(list) => {
                let n = xs.rows();
                let mut per_class: Vec<(u8, Vec<f64>)> = Vec::with_capacity(list.len());
                for (c, s) in list {
                    let mut vals = vec![0.0f64; n];
                    s.decide_many(xs, &mut vals);
                    per_class.push((*c, vals));
                }
                (0..n)
                    .map(|q| {
                        let scores: Vec<(u8, f64)> =
                            per_class.iter().map(|(c, v)| (*c, v[q])).collect();
                        multiclass_decision(scores)
                    })
                    .collect()
            }
            ScorerKind::Voting(list) => {
                let n = xs.rows();
                let mut per_member: Vec<Vec<f64>> = Vec::with_capacity(list.len());
                for s in list {
                    let mut vals = vec![0.0f64; n];
                    s.decide_many(xs, &mut vals);
                    per_member.push(vals);
                }
                let mut row = vec![0.0f64; list.len()];
                (0..n)
                    .map(|q| {
                        for (j, vals) in per_member.iter().enumerate() {
                            row[j] = vals[q];
                        }
                        let (value, label) = ensemble::vote(&row);
                        Decision::Binary { value, label }
                    })
                    .collect()
            }
        }
    }

    /// Run the whole batch on the device when the PJRT path is attached.
    /// Any device failure returns `None` and the caller falls back to
    /// the rust tiles — a broken artifact degrades throughput, never
    /// availability.
    fn device_batch(&self, xs: &Matrix) -> Option<Vec<f64>> {
        let dev = self.device.as_ref()?;
        let mut g = lock_recover(dev);
        let st = &mut *g;
        match st.dec.decision_batch(&mut st.rt, xs) {
            Ok(vals) => {
                self.device_batches.fetch_add(1, Ordering::Relaxed);
                Some(vals)
            }
            Err(_) => None,
        }
    }
}

fn multiclass_scorers(mc: &MulticlassModel, mode: ScoreMode) -> Vec<(u8, BinaryScorer)> {
    mc.jobs
        .iter()
        .filter_map(|j| {
            j.model
                .as_ref()
                .map(|m| (j.class_id, BinaryScorer::with_mode(m.model.clone(), mode)))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// The shared model handle
// ---------------------------------------------------------------------------

/// Hot-swappable model handle: an `Arc<ArtifactScorer>` behind an
/// `RwLock`. Workers `get()` the current scorer at the start of each
/// batch (batches already popped finish on the scorer they started
/// with); `swap()` installs a new model for everything after. The slot is
/// shared by `Arc` between an [`Engine`] and whoever manages its models
/// (the [`crate::serve::manager::EngineManager`]), so reloads need no
/// engine-specific plumbing.
pub struct ModelSlot {
    scorer: RwLock<Arc<ArtifactScorer>>,
}

impl ModelSlot {
    /// Prepare `artifact` for serving and wrap it in a slot.
    pub fn new(artifact: &ModelArtifact) -> Result<ModelSlot> {
        Ok(ModelSlot {
            scorer: RwLock::new(Arc::new(ArtifactScorer::new(artifact)?)),
        })
    }

    /// The scorer currently installed (cheap: one `Arc` clone under a
    /// read lock). Poisoning is recovered: a swap never leaves the slot
    /// half-written (the new `Arc` is built before the write lock), so
    /// whatever is installed is always a complete scorer.
    pub fn get(&self) -> Arc<ArtifactScorer> {
        Arc::clone(&self.scorer.read().unwrap_or_else(|e| e.into_inner()))
    }

    /// Install a new model. Fails (leaving the old model in place) if the
    /// artifact cannot be prepared for serving.
    pub fn swap(&self, artifact: &ModelArtifact) -> Result<()> {
        let scorer = ArtifactScorer::new(artifact)?;
        self.install(Arc::new(scorer));
        Ok(())
    }

    /// Install an already-prepared scorer — the canary-promotion path:
    /// the scorer was built when the canary deploy started, so promoting
    /// it must not pay a second prepare (and cannot fail).
    pub fn install(&self, scorer: Arc<ArtifactScorer>) {
        *self.scorer.write().unwrap_or_else(|e| e.into_inner()) = scorer;
    }
}

// ---------------------------------------------------------------------------
// The threaded engine
// ---------------------------------------------------------------------------

/// Engine tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Flush a batch at this size.
    pub max_batch: usize,
    /// Flush a partial batch after this wait (tail-latency bound).
    pub max_wait: Duration,
    /// Worker threads evaluating batches.
    pub workers: usize,
    /// Bounded queue capacity; `submit` blocks (backpressure) at the cap.
    pub queue_cap: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            workers: crate::util::pool::num_threads().clamp(1, 4),
            queue_cap: 1024,
        }
    }
}

struct Request {
    x: Vec<f32>,
    enqueued: Instant,
    tx: mpsc::Sender<std::result::Result<Decision, String>>,
    /// Set by [`Ticket::wait_deadline`] when the server-side deadline
    /// expires: the batcher skips the request instead of scoring work
    /// nobody is waiting for.
    cancelled: Arc<AtomicBool>,
}

struct QueueInner {
    pending: VecDeque<Request>,
    /// False once shutdown begins: submits are rejected, workers drain
    /// what is left and exit.
    open: bool,
    /// One-shot flush request ([`Engine::kick`]): the next batch pops
    /// immediately even if neither the size nor the deadline trigger is
    /// due. The graceful-drain path uses this to complete parked partial
    /// batches without closing the queue.
    kick: bool,
}

struct Shared {
    cfg: EngineConfig,
    q: Mutex<QueueInner>,
    /// Signaled when work arrives or shutdown begins.
    work: Condvar,
    /// Signaled when a batch is drained (queue has space again).
    space: Condvar,
    slot: Arc<ModelSlot>,
    stats: Arc<EngineStats>,
    faults: Arc<FaultPlan>,
}

/// A pending prediction: wait on it to get the [`Decision`].
pub struct Ticket {
    rx: mpsc::Receiver<std::result::Result<Decision, String>>,
    cancelled: Arc<AtomicBool>,
    stats: Arc<EngineStats>,
}

impl Ticket {
    /// Block until the decision is ready.
    pub fn wait(self) -> Result<Decision> {
        match self.rx.recv() {
            Ok(Ok(d)) => Ok(d),
            Ok(Err(msg)) => Err(Error::Serve(msg)),
            Err(_) => Err(Error::Serve("engine dropped the request".into())),
        }
    }

    /// Block up to `timeout` (used by tests to turn a lost wakeup into a
    /// failure instead of a hang).
    pub fn wait_timeout(self, timeout: Duration) -> Result<Decision> {
        match self.rx.recv_timeout(timeout) {
            Ok(Ok(d)) => Ok(d),
            Ok(Err(msg)) => Err(Error::Serve(msg)),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                Err(Error::Serve("timed out waiting for a decision".into()))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(Error::Serve("engine dropped the request".into()))
            }
        }
    }

    /// Deadline-bounded wait for the serving path. `None` means the
    /// deadline expired: the ticket is cancelled (the batcher will skip
    /// the request and count it completed, so `in_flight` still drains)
    /// and the expiry is counted in the engine's `timeouts` stat — the
    /// caller owns the timeout response (503 + `Retry-After`). Results
    /// and engine-side errors come back as `Some`.
    pub fn wait_deadline(self, timeout: Duration) -> Option<Result<Decision>> {
        use std::sync::atomic::Ordering;
        match self.rx.recv_timeout(timeout) {
            Ok(Ok(d)) => Some(Ok(d)),
            Ok(Err(msg)) => Some(Err(Error::Serve(msg))),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                self.cancelled.store(true, Ordering::SeqCst);
                self.stats.timeouts.fetch_add(1, Ordering::Relaxed);
                None
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Some(Err(Error::Serve("engine dropped the request".into())))
            }
        }
    }
}

/// The concurrent dynamic-batching decision engine.
pub struct Engine {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Engine {
    /// Start an engine serving `artifact` under `cfg` (spawns the worker
    /// threads immediately). The engine owns its slot; use
    /// [`Engine::with_slot`] to share one with a manager.
    pub fn new(artifact: &ModelArtifact, cfg: EngineConfig) -> Result<Engine> {
        Engine::with_slot(Arc::new(ModelSlot::new(artifact)?), cfg)
    }

    /// Start an engine evaluating whatever `slot` holds. The caller keeps
    /// its own `Arc` to the slot and may swap models through it at any
    /// time — this is how the manager hot-reloads without reaching into
    /// the engine.
    pub fn with_slot(slot: Arc<ModelSlot>, cfg: EngineConfig) -> Result<Engine> {
        Engine::with_slot_faults(slot, cfg, FaultPlan::disarmed())
    }

    /// [`Engine::with_slot`] with a fault plan wired into the workers
    /// (the chaos-test/CLI `--fault-plan` path; a disarmed plan is free).
    pub fn with_slot_faults(
        slot: Arc<ModelSlot>,
        cfg: EngineConfig,
        faults: Arc<FaultPlan>,
    ) -> Result<Engine> {
        let cfg = EngineConfig {
            max_batch: cfg.max_batch.max(1),
            workers: cfg.workers.max(1),
            queue_cap: cfg.queue_cap.max(cfg.max_batch.max(1)),
            ..cfg
        };
        let shared = Arc::new(Shared {
            cfg,
            q: Mutex::new(QueueInner {
                pending: VecDeque::new(),
                open: true,
                kick: false,
            }),
            work: Condvar::new(),
            space: Condvar::new(),
            slot,
            stats: Arc::new(EngineStats::new()),
            faults,
        });
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let sh = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("serve-engine-{w}"))
                .spawn(move || {
                    // Per-batch scoring panics are caught inside
                    // `worker_loop`; this outer guard catches anything
                    // else that unwinds (queue plumbing, allocation) and
                    // re-enters the loop so one panic cannot permanently
                    // shrink the worker pool. Bounded: a deterministic
                    // crash-on-entry must not spin forever.
                    for _ in 0..=WORKER_RESPAWN_CAP {
                        let exit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            worker_loop(&sh)
                        }));
                        match exit {
                            Ok(()) => break, // normal shutdown
                            Err(_) => {
                                sh.stats
                                    .worker_panics
                                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                        }
                    }
                })
                .map_err(|e| Error::Serve(format!("spawning engine worker: {e}")))?;
            workers.push(handle);
        }
        Ok(Engine { shared, workers })
    }

    /// Feature dimensionality the current model expects.
    pub fn dim(&self) -> usize {
        self.shared.slot.get().dim()
    }

    /// "binary" or "multiclass" for the current model.
    pub fn model_kind(&self) -> &'static str {
        self.shared.slot.get().kind_name()
    }

    /// Bytes of support-vector data the current model pins resident
    /// (see [`ArtifactScorer::resident_bytes`]).
    pub fn resident_bytes(&self) -> u64 {
        self.shared.slot.get().resident_bytes()
    }

    /// The shared model slot (swap models through it to hot-reload; the
    /// engine's own [`Engine::reload`] goes through the same slot and
    /// additionally counts the reload in the stats).
    pub fn slot(&self) -> Arc<ModelSlot> {
        Arc::clone(&self.shared.slot)
    }

    /// The engine configuration in force.
    pub fn config(&self) -> EngineConfig {
        self.shared.cfg
    }

    /// Enqueue one query. Blocks while the bounded queue is full
    /// (backpressure); errors if the dimension is wrong or the engine is
    /// shut down.
    pub fn submit(&self, x: &[f32]) -> Result<Ticket> {
        let dim = self.dim();
        if x.len() != dim {
            return Err(Error::invalid(format!(
                "query has {} features, model expects {dim}",
                x.len()
            )));
        }
        let (tx, rx) = mpsc::channel();
        let cancelled = Arc::new(AtomicBool::new(false));
        let req = Request {
            x: x.to_vec(),
            enqueued: Instant::now(),
            tx,
            cancelled: Arc::clone(&cancelled),
        };
        let mut q = lock_recover(&self.shared.q);
        let mut counted_wait = false;
        while q.open && q.pending.len() >= self.shared.cfg.queue_cap {
            // Count submits that experienced backpressure, not condvar
            // wakeups (notify_all wakes every blocked submitter per
            // drained batch).
            if !counted_wait {
                counted_wait = true;
                self.shared
                    .stats
                    .backpressure_waits
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
            q = self
                .shared
                .space
                .wait(q)
                .unwrap_or_else(|e| e.into_inner());
        }
        if !q.open {
            return Err(Error::Serve("engine is shut down".into()));
        }
        q.pending.push_back(req);
        self.shared
            .stats
            .requests
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        drop(q);
        self.shared.work.notify_one();
        Ok(Ticket {
            rx,
            cancelled,
            stats: Arc::clone(&self.shared.stats),
        })
    }

    /// Submit one query and wait for its decision.
    pub fn predict(&self, x: &[f32]) -> Result<Decision> {
        self.submit(x)?.wait()
    }

    /// Submit every row of `xs` and collect the decisions in row order
    /// (fills batches; this is the high-throughput path).
    pub fn predict_many(&self, xs: &Matrix) -> Result<Vec<Decision>> {
        let mut tickets = Vec::with_capacity(xs.rows());
        for i in 0..xs.rows() {
            tickets.push(self.submit(xs.row(i))?);
        }
        tickets.into_iter().map(|t| t.wait()).collect()
    }

    /// Swap the served model in place. Batches a worker has already
    /// popped finish on the scorer they started with; everything still
    /// queued — and every later submit — is answered by the new model.
    pub fn reload(&self, artifact: &ModelArtifact) -> Result<()> {
        self.shared.slot.swap(artifact)?;
        self.shared
            .stats
            .reloads
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(())
    }

    /// Swap in an already-prepared scorer (the canary-promotion path —
    /// same slot semantics as [`Engine::reload`], counted as a reload).
    pub fn install(&self, scorer: Arc<ArtifactScorer>) {
        self.shared.slot.install(scorer);
        self.shared
            .stats
            .reloads
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    /// Point-in-time counters.
    pub fn stats(&self) -> StatsSnapshot {
        self.shared.stats.snapshot()
    }

    /// Requests currently queued (not yet evaluated).
    pub fn queued(&self) -> usize {
        lock_recover(&self.shared.q).pending.len()
    }

    /// Ask the workers to flush whatever is pending right now, without
    /// closing the queue. The graceful-drain loop calls this repeatedly
    /// so parked partial batches (waiting on `max_wait`) complete
    /// promptly while new requests are still being accepted.
    pub fn kick(&self) {
        let mut q = lock_recover(&self.shared.q);
        if q.pending.is_empty() {
            return;
        }
        q.kick = true;
        drop(q);
        self.shared.work.notify_all();
    }

    /// Requests accepted but not yet answered (queued or mid-batch). The
    /// manager's eviction paths use this: an engine with in-flight work
    /// is never dropped out from under its waiters.
    pub fn in_flight(&self) -> u64 {
        use std::sync::atomic::Ordering::Relaxed;
        let completed = self.shared.stats.completed.load(Relaxed);
        self.shared
            .stats
            .requests
            .load(Relaxed)
            .saturating_sub(completed)
    }

    fn begin_shutdown(&self) {
        let mut q = lock_recover(&self.shared.q);
        q.open = false;
        drop(q);
        self.shared.work.notify_all();
        self.shared.space.notify_all();
    }

    /// Stop accepting requests, drain the queue, and join the workers.
    pub fn shutdown(mut self) {
        self.begin_shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.begin_shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Why a worker popped a batch (drives the stats attribution).
enum TakeKind {
    /// The queue reached `max_batch`; slots are fully used.
    Size,
    /// The deadline fired on a partial batch; padding is real.
    Deadline,
    /// Shutdown or [`Engine::kick`] drain: no deadline fired and nothing
    /// was waiting to fill the batch, so it neither counts as a deadline
    /// flush nor as padded slots.
    Drain,
}

/// Pop the next due batch, blocking on the condvar until one is due or
/// shutdown drains the queue. Returns `None` when the engine is closed
/// and empty.
fn next_batch(shared: &Shared) -> Option<(Vec<Request>, TakeKind)> {
    let cfg = &shared.cfg;
    let policy = FlushPolicy::new(cfg.max_batch, cfg.max_wait);
    let mut q = lock_recover(&shared.q);
    let kind = loop {
        if q.pending.is_empty() {
            q.kick = false;
            if !q.open {
                return None;
            }
            // Park until work arrives; bounded so a shutdown missed by a
            // race still gets observed promptly.
            let (nq, _) = shared
                .work
                .wait_timeout(q, Duration::from_millis(50))
                .unwrap_or_else(|e| e.into_inner());
            q = nq;
            continue;
        }
        if !q.open || q.kick {
            q.kick = false;
            break TakeKind::Drain;
        }
        let oldest = q.pending.front().map(|r| r.enqueued);
        match policy.due(q.pending.len(), oldest) {
            Some(FlushReason::Size) => break TakeKind::Size,
            Some(FlushReason::Deadline) => break TakeKind::Deadline,
            None => {
                let wait = policy
                    .time_left(oldest)
                    .unwrap_or(Duration::from_millis(50))
                    .max(Duration::from_micros(50));
                let (nq, _) = shared
                    .work
                    .wait_timeout(q, wait)
                    .unwrap_or_else(|e| e.into_inner());
                q = nq;
            }
        }
    };
    let take = q.pending.len().min(cfg.max_batch);
    let batch: Vec<Request> = q.pending.drain(..take).collect();
    drop(q);
    shared.space.notify_all();
    Some((batch, kind))
}

/// Best-effort text out of a panic payload (panics carry `&str` or
/// `String` in practice).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

fn worker_loop(shared: &Shared) {
    use std::sync::atomic::Ordering::Relaxed;
    use std::sync::atomic::Ordering::SeqCst;
    while let Some((batch, kind)) = next_batch(shared) {
        let batch_len = batch.len() as u64;
        let scorer = shared.slot.get();
        let dim = scorer.dim();
        // Cancelled requests (server-side deadline expired) are dropped
        // before scoring: nobody is listening for the reply. Counting
        // them completed here is what keeps `in_flight` draining.
        let (live, dead): (Vec<Request>, Vec<Request>) = batch
            .into_iter()
            .partition(|r| !r.cancelled.load(SeqCst));
        shared.stats.completed.fetch_add(dead.len() as u64, Relaxed);
        drop(dead);
        // A reload between submit and evaluation can change the expected
        // dimensionality; answer mismatched requests with an error rather
        // than poisoning the batch.
        let (ok, bad): (Vec<Request>, Vec<Request>) =
            live.into_iter().partition(|r| r.x.len() == dim);
        for r in bad {
            // An error reply still answers the request — count it, so
            // `in_flight` drains to zero and eviction is not blocked
            // forever by a rejected query.
            shared.stats.completed.fetch_add(1, Relaxed);
            let _ = r.tx.send(Err(format!(
                "query has {} features, model expects {dim} (model reloaded?)",
                r.x.len()
            )));
        }
        if ok.is_empty() {
            continue;
        }
        let mut m = Matrix::zeros(ok.len(), dim);
        for (r, req) in ok.iter().enumerate() {
            m.row_mut(r).copy_from_slice(&req.x);
        }
        // Panic isolation: a panic in scoring (a poisoned model, a bug
        // in a kernel path, or an injected chaos fault) fails this
        // batch's tickets with an error and leaves the worker serving
        // the next batch — it must never abort the process or strand
        // waiters.
        let scored = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if shared.faults.worker_batch() {
                panic!("injected fault: worker panic on batch");
            }
            scorer.decide_batch(&m)
        }));
        shared.stats.batches.fetch_add(1, Relaxed);
        let slots = match kind {
            TakeKind::Size | TakeKind::Deadline => shared.cfg.max_batch as u64,
            TakeKind::Drain => batch_len,
        };
        shared.stats.slots.fetch_add(slots, Relaxed);
        if matches!(kind, TakeKind::Deadline) {
            shared.stats.deadline_flushes.fetch_add(1, Relaxed);
        }
        match scored {
            Ok(decisions) => {
                for (req, d) in ok.into_iter().zip(decisions) {
                    shared.stats.latency.record_duration(req.enqueued.elapsed());
                    shared.stats.completed.fetch_add(1, Relaxed);
                    let _ = req.tx.send(Ok(d));
                }
            }
            Err(payload) => {
                shared.stats.worker_panics.fetch_add(1, Relaxed);
                let msg = format!("scoring panicked: {}", panic_message(payload.as_ref()));
                for req in ok {
                    shared.stats.completed.fetch_add(1, Relaxed);
                    let _ = req.tx.send(Err(msg.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::two_gaussians;
    use crate::svm::smo::{train, SvmParams};
    use crate::util::rng::Pcg64;

    fn fixture() -> (SvmModel, crate::data::dataset::Dataset) {
        let mut rng = Pcg64::seed_from(77);
        let ds = two_gaussians(120, 80, 6, 3.0, &mut rng);
        let p = SvmParams {
            kernel: KernelKind::Rbf { gamma: 0.2 },
            ..Default::default()
        };
        (train(&ds.points, &ds.labels, &p).unwrap(), ds)
    }

    #[test]
    fn flush_policy_triggers() {
        let p = FlushPolicy::new(4, Duration::from_millis(100));
        assert_eq!(p.due(0, None), None);
        assert_eq!(p.due(4, Some(Instant::now())), Some(FlushReason::Size));
        assert_eq!(p.due(1, Some(Instant::now())), None);
        let past = Instant::now() - Duration::from_millis(200);
        assert_eq!(p.due(1, Some(past)), Some(FlushReason::Deadline));
        // max_batch clamps to 1
        assert_eq!(FlushPolicy::new(0, Duration::ZERO).max_batch, 1);
    }

    #[test]
    fn batch_queue_round_trips_tickets() {
        let (model, ds) = fixture();
        let scorer = BinaryScorer::new(model);
        let mut q = BatchQueue::new(16, Duration::from_secs(1));
        let ids: Vec<u64> = (0..40).map(|i| q.submit(ds.points.row(i))).collect();
        assert_eq!(q.due(), Some(FlushReason::Size));
        while let Some((bids, m)) = q.next_batch(false) {
            let vals: Vec<f64> = (0..m.rows()).map(|r| scorer.decide(m.row(r))).collect();
            q.complete(&bids, vals);
        }
        assert_eq!(q.stats().batches, 3);
        assert_eq!(q.stats().requests, 40);
        for (i, id) in ids.iter().enumerate() {
            let got = q.take(*id).unwrap();
            assert_eq!(got, scorer.decide(ds.points.row(i)));
        }
        assert!(q.take(ids[0]).is_none(), "results are taken once");
    }

    #[test]
    fn scorer_matches_model_decision() {
        let (model, ds) = fixture();
        let scorer = BinaryScorer::new(model.clone());
        for i in (0..ds.len()).step_by(11) {
            let want = model.decision(ds.points.row(i));
            let got = scorer.decide(ds.points.row(i));
            assert!(
                (got - want).abs() <= 1e-6 * want.abs().max(1.0),
                "row {i}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn engine_answers_and_batches() {
        let (model, ds) = fixture();
        let art = ModelArtifact::Svm(model.clone());
        let engine = Engine::new(
            &art,
            EngineConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                workers: 2,
                queue_cap: 64,
            },
        )
        .unwrap();
        let decisions = engine.predict_many(&ds.points).unwrap();
        assert_eq!(decisions.len(), ds.len());
        let scorer = BinaryScorer::new(model.clone());
        for (i, d) in decisions.iter().enumerate() {
            let Decision::Binary { value, label } = d else {
                panic!("binary model must give binary decisions")
            };
            assert_eq!(*value, scorer.decide(ds.points.row(i)), "row {i}");
            assert_eq!(*label, if *value > 0.0 { 1 } else { -1 });
        }
        let st = engine.stats();
        assert_eq!(st.completed, ds.len() as u64);
        assert!(st.batches >= (ds.len() / 8) as u64 / 2, "batching happened");
        engine.shutdown();
    }

    #[test]
    fn concurrent_submitters_get_sequential_answers() {
        let (model, ds) = fixture();
        let art = ModelArtifact::Svm(model.clone());
        let engine = Engine::new(
            &art,
            EngineConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(200),
                workers: 3,
                queue_cap: 32,
            },
        )
        .unwrap();
        let scorer = BinaryScorer::new(model.clone());
        let n_threads = 6;
        let per_thread = 50;
        std::thread::scope(|s| {
            let engine = &engine;
            let scorer = &scorer;
            let ds = &ds;
            for t in 0..n_threads {
                s.spawn(move || {
                    for r in 0..per_thread {
                        let i = (t * 31 + r * 7) % ds.len();
                        let d = engine
                            .submit(ds.points.row(i))
                            .unwrap()
                            .wait_timeout(Duration::from_secs(20))
                            .unwrap();
                        let Decision::Binary { value, .. } = d else {
                            panic!("binary decision expected")
                        };
                        // Bit-identical to the sequential scorer: batching
                        // and thread interleaving must not change values.
                        assert_eq!(value, scorer.decide(ds.points.row(i)), "row {i}");
                        // And within f32-dot rounding of the pointwise model.
                        let want = model.decision(ds.points.row(i));
                        assert!((value - want).abs() <= 1e-6 * want.abs().max(1.0));
                    }
                });
            }
        });
        let st = engine.stats();
        assert_eq!(st.completed, (n_threads * per_thread) as u64);
    }

    #[test]
    fn deadline_trickle_never_stalls() {
        let (model, ds) = fixture();
        let art = ModelArtifact::Svm(model);
        let engine = Engine::new(
            &art,
            EngineConfig {
                max_batch: 64, // never filled by a trickle
                max_wait: Duration::from_millis(1),
                workers: 1,
                queue_cap: 64,
            },
        )
        .unwrap();
        for i in 0..25 {
            let t = engine.submit(ds.points.row(i)).unwrap();
            t.wait_timeout(Duration::from_secs(10))
                .expect("trickle request must flush by deadline");
        }
        let st = engine.stats();
        assert_eq!(st.completed, 25);
        assert!(st.deadline_flushes > 0, "deadline must have triggered");
        assert!(st.utilization < 0.5, "trickle batches are padded");
    }

    #[test]
    fn backpressure_blocks_but_completes() {
        let (model, ds) = fixture();
        let art = ModelArtifact::Svm(model);
        let engine = Engine::new(
            &art,
            EngineConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(100),
                workers: 1,
                queue_cap: 4, // tiny: submitters must wait
            },
        )
        .unwrap();
        let n_threads = 4;
        let per_thread = 30;
        std::thread::scope(|s| {
            let engine = &engine;
            let ds = &ds;
            for t in 0..n_threads {
                s.spawn(move || {
                    for r in 0..per_thread {
                        let i = (t + r * 13) % ds.len();
                        engine
                            .submit(ds.points.row(i))
                            .unwrap()
                            .wait_timeout(Duration::from_secs(20))
                            .unwrap();
                    }
                });
            }
        });
        let st = engine.stats();
        assert_eq!(st.completed, (n_threads * per_thread) as u64);
    }

    #[test]
    fn shutdown_drains_outstanding_tickets() {
        let (model, ds) = fixture();
        let art = ModelArtifact::Svm(model);
        let engine = Engine::new(
            &art,
            EngineConfig {
                max_batch: 128,
                max_wait: Duration::from_secs(3600), // only shutdown flushes
                workers: 1,
                queue_cap: 128,
            },
        )
        .unwrap();
        let tickets: Vec<Ticket> = (0..10)
            .map(|i| engine.submit(ds.points.row(i)).unwrap())
            .collect();
        engine.shutdown();
        for t in tickets {
            t.wait_timeout(Duration::from_secs(10))
                .expect("shutdown must drain queued requests");
        }
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let (model, ds) = fixture();
        let art = ModelArtifact::Svm(model);
        let engine = Engine::new(&art, EngineConfig::default()).unwrap();
        engine.begin_shutdown();
        assert!(engine.submit(ds.points.row(0)).is_err());
    }

    #[test]
    fn dimension_mismatch_is_rejected_at_submit() {
        let (model, _) = fixture();
        let art = ModelArtifact::Svm(model);
        let engine = Engine::new(&art, EngineConfig::default()).unwrap();
        assert!(engine.submit(&[1.0, 2.0]).is_err());
    }

    #[test]
    fn reload_swaps_decisions() {
        let (model, ds) = fixture();
        // A second model trained with a different gamma gives different
        // decision values.
        let p2 = SvmParams {
            kernel: KernelKind::Rbf { gamma: 2.0 },
            ..Default::default()
        };
        let model2 = train(&ds.points, &ds.labels, &p2).unwrap();
        let engine = Engine::new(&ModelArtifact::Svm(model.clone()), EngineConfig::default())
            .unwrap();
        let before = engine.predict(ds.points.row(0)).unwrap();
        engine.reload(&ModelArtifact::Svm(model2.clone())).unwrap();
        let after = engine.predict(ds.points.row(0)).unwrap();
        let (Decision::Binary { value: b, .. }, Decision::Binary { value: a, .. }) =
            (&before, &after)
        else {
            panic!("binary decisions expected")
        };
        let s2 = BinaryScorer::new(model2);
        assert_eq!(*a, s2.decide(ds.points.row(0)));
        assert_ne!(*a, *b, "reload must change the served model");
        assert_eq!(engine.stats().reloads, 1);
    }

    #[test]
    fn worker_panic_fails_batch_but_engine_keeps_serving() {
        let (model, ds) = fixture();
        let slot = Arc::new(ModelSlot::new(&ModelArtifact::Svm(model.clone())).unwrap());
        let faults = FaultPlan::disarmed();
        faults.panic_on_batch(1);
        let engine = Engine::with_slot_faults(
            Arc::clone(&slot),
            EngineConfig {
                max_batch: 4,
                max_wait: Duration::from_secs(3600), // size flushes only
                workers: 1,
                queue_cap: 64,
            },
            Arc::clone(&faults),
        )
        .unwrap();
        // First batch: the armed fault panics scoring; every ticket of
        // the batch errors instead of hanging, and the process survives.
        let tickets: Vec<Ticket> = (0..4)
            .map(|i| engine.submit(ds.points.row(i)).unwrap())
            .collect();
        for t in tickets {
            let err = t
                .wait_timeout(Duration::from_secs(10))
                .expect_err("faulted batch must error");
            assert!(
                err.to_string().contains("panicked"),
                "error should name the panic: {err}"
            );
        }
        // The engine keeps serving, bit-identical to a fresh scorer.
        let scorer = BinaryScorer::new(model);
        let tickets: Vec<Ticket> = (0..8)
            .map(|i| engine.submit(ds.points.row(i)).unwrap())
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let d = t.wait_timeout(Duration::from_secs(10)).unwrap();
            let Decision::Binary { value, .. } = d else {
                panic!("binary decision expected")
            };
            assert_eq!(value, scorer.decide(ds.points.row(i)), "row {i}");
        }
        let st = engine.stats();
        assert_eq!(st.worker_panics, 1);
        assert_eq!(st.completed, 12);
        assert_eq!(engine.in_flight(), 0, "errors still count as answered");
        assert_eq!(faults.injected().panics, 1);
    }

    #[test]
    fn wait_deadline_cancels_parked_request() {
        let (model, ds) = fixture();
        let engine = Engine::new(
            &ModelArtifact::Svm(model),
            EngineConfig {
                max_batch: 8,
                max_wait: Duration::from_secs(3600), // parked: never flushes
                workers: 1,
                queue_cap: 64,
            },
        )
        .unwrap();
        let t = engine.submit(ds.points.row(0)).unwrap();
        assert!(
            t.wait_deadline(Duration::from_millis(20)).is_none(),
            "parked batch cannot answer before the deadline"
        );
        assert_eq!(engine.stats().timeouts, 1);
        // The cancelled request is skipped (not scored) on the next
        // flush and still counts completed, so in_flight drains.
        engine.kick();
        while engine.in_flight() > 0 {
            std::thread::yield_now();
        }
        let st = engine.stats();
        assert_eq!(st.completed, 1);
        assert_eq!(st.batches, 0, "a fully-cancelled batch is never scored");
    }

    #[test]
    fn kick_flushes_parked_partial_batch_without_closing() {
        let (model, ds) = fixture();
        let engine = Engine::new(
            &ModelArtifact::Svm(model.clone()),
            EngineConfig {
                max_batch: 8,
                max_wait: Duration::from_secs(3600),
                workers: 1,
                queue_cap: 64,
            },
        )
        .unwrap();
        let tickets: Vec<Ticket> = (0..3)
            .map(|i| engine.submit(ds.points.row(i)).unwrap())
            .collect();
        engine.kick();
        let scorer = BinaryScorer::new(model);
        for (i, t) in tickets.into_iter().enumerate() {
            let d = t
                .wait_timeout(Duration::from_secs(10))
                .expect("kick must flush the parked batch");
            let Decision::Binary { value, .. } = d else {
                panic!("binary decision expected")
            };
            assert_eq!(value, scorer.decide(ds.points.row(i)), "row {i}");
        }
        let st = engine.stats();
        assert_eq!(st.completed, 3);
        assert_eq!(st.deadline_flushes, 0, "kick is a drain, not a deadline");
        assert_eq!(st.slots, 3, "drain batches count only real slots");
        // The queue stayed open: later submits still work (the dropped
        // ticket is answered by the shutdown drain when `engine` drops).
        assert!(engine.submit(ds.points.row(5)).is_ok());
    }

    #[test]
    fn shared_slot_swaps_models_from_outside_the_engine() {
        // The manager-style reload: whoever holds the other Arc to the
        // slot swaps the model; the engine's workers pick it up without
        // any engine API involved.
        let (model, ds) = fixture();
        let slot = Arc::new(ModelSlot::new(&ModelArtifact::Svm(model.clone())).unwrap());
        let engine = Engine::with_slot(Arc::clone(&slot), EngineConfig::default()).unwrap();
        let before = engine.predict(ds.points.row(0)).unwrap();
        let p2 = SvmParams {
            kernel: KernelKind::Rbf { gamma: 3.0 },
            ..Default::default()
        };
        let model2 = train(&ds.points, &ds.labels, &p2).unwrap();
        slot.swap(&ModelArtifact::Svm(model2.clone())).unwrap();
        let after = engine.predict(ds.points.row(0)).unwrap();
        let (Decision::Binary { value: b, .. }, Decision::Binary { value: a, .. }) =
            (&before, &after)
        else {
            panic!("binary decisions expected")
        };
        assert_eq!(*a, BinaryScorer::new(model2).decide(ds.points.row(0)));
        assert_ne!(*a, *b, "slot swap must change the served model");
    }
}
