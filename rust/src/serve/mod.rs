//! The serving layer: model registry (text + binary formats), per-model
//! batching engines behind a manager, a routed HTTP front end, and
//! serving statistics.
//!
//! This is the path from a trained model to the ROADMAP's "heavy traffic"
//! north star. The pieces compose bottom-up:
//!
//! * [`registry`] — named-model persistence. The current write format is
//!   **v2 binary** ([`binary`]: length-prefixed little-endian sections,
//!   bit-exact f64/f32 round-trip, loads at I/O speed); v1 text and
//!   legacy `SvmModel` line files still load transparently, and
//!   [`Registry::migrate`] (or `mlsvm registry migrate`) rewrites a
//!   directory in place;
//! * [`engine`] — a thread-safe dynamic-batching decision engine
//!   (Mutex+Condvar bounded queue, size- and deadline-triggered flushes,
//!   worker threads, tiled batched kernel evaluation, per-class argmax).
//!   The model it evaluates lives in a hot-swappable [`ModelSlot`] shared
//!   with whoever manages it. Its single-threaded core,
//!   [`engine::BatchQueue`], is what [`crate::coordinator::Router`]
//!   wraps;
//! * [`manager`] — multi-model serving: an [`EngineManager`] lazily
//!   spawns one engine per registry name, with per-model flush policies,
//!   hot reload/evict, per-model stats snapshots, and capacity
//!   management ([`ManagerConfig`]: an LRU-evicting resident cap plus
//!   idle-engine reaping, neither of which drops an engine with
//!   in-flight work);
//! * [`server`] — a hand-rolled HTTP/1.1-over-TCP front end routing
//!   `/v1/models/{name}/predict|predict-batch|stats|reload|evict` plus a
//!   `/v1/models` listing; the legacy unprefixed routes map to a default
//!   model. Connections keep-alive and **pipeline**: back-to-back
//!   requests on one socket are parsed by a persistent buffered reader
//!   and answered in order (depth/byte bounded);
//! * [`route`] — the fleet router tier: `mlsvm route` fronts N backend
//!   serve processes behind one address, consistent-hashing model names
//!   across them (FNV-1a ring keyed by stable backend indices, so
//!   placement survives restarts), health-checking `/healthz`, pooling
//!   keep-alive backend connections, retrying evict/connect failures
//!   against the next ring replica under a bounded budget, and fanning
//!   out the fleet-wide routes (`/v1/models`, `/stats`, `/healthz`);
//! * [`stats`] — batching counters and log-spaced latency histograms,
//!   snapshotted as JSON per model and aggregated fleet-wide;
//! * [`faults`] — a deterministic fault-injection plan ([`FaultPlan`])
//!   whose hooks live on the production paths (worker batches, registry
//!   opens, accepted sockets) but stay disarmed unless a chaos test or
//!   the hidden `--fault-plan` flag arms them.
//!
//! End to end: `mlsvm train --registry models --name m` → `mlsvm serve
//! --registry models --models m,n` → routed HTTP predictions; `cargo
//! bench --bench serve` drives the closed-loop loadgen (single- and
//! multi-model) against it and measures v1-vs-v2 model load time.

pub mod binary;
pub mod engine;
pub mod faults;
pub mod manager;
pub mod registry;
pub mod route;
pub mod server;
pub mod stats;

pub use engine::{
    score_mode, set_score_mode, ArtifactScorer, BatchQueue, Decision, Engine, EngineConfig,
    FlushPolicy, FlushReason, ModelSlot, ScoreMode, ScorerLayout, Ticket, QUANT_AGREEMENT_FLOOR,
};
pub use faults::{FaultCounters, FaultPlan, LoadFault};
pub use manager::{
    decisions_agree, routes_to_canary, CanaryPolicy, CanaryView, CircuitState, CircuitView,
    EngineManager, LifecycleView, ManagedEngine, ManagerConfig, BREAKER_COOLDOWN,
    BREAKER_THRESHOLD, CANARY_AGREEMENT_FLOOR, CANARY_MAX_ERRORS, CANARY_MIN_SAMPLES,
    CANARY_PROMOTE_AGREEMENT,
};
pub use registry::{
    detect_format, load_artifact, save_artifact, save_artifact_v1, write_atomic, MigrationReport,
    ModelArtifact, ModelFormat, Registry, VersionEntry, DEFAULT_KEEP_VERSIONS,
};
pub use route::{failover_backoff, BackendsUpdate, Ring, Router, RouterConfig};
pub use server::{
    http_pipeline_on, http_request, http_request_on, http_request_with_auth, ServeState, Server,
    MAX_PIPELINE_DEPTH, STREAM_THRESHOLD,
};
pub use stats::{
    aggregate, BatchStats, CanarySnapshot, CanaryStats, EngineStats, FleetCapacity,
    LatencyHistogram, StatsSnapshot,
};
