//! The serving layer: model registry, concurrent batching engine, HTTP
//! front end, and serving statistics.
//!
//! This is the path from a trained model to the ROADMAP's "heavy traffic"
//! north star. The pieces compose bottom-up:
//!
//! * [`registry`] — versioned multi-section persistence for
//!   [`crate::svm::model::SvmModel`], [`crate::mlsvm::trainer::MlsvmModel`]
//!   and [`crate::coordinator::jobs::MulticlassModel`], plus a named-model
//!   registry directory (save / load / list, legacy files included);
//! * [`engine`] — a thread-safe dynamic-batching decision engine
//!   (Mutex+Condvar bounded queue, size- and deadline-triggered flushes,
//!   worker threads, tiled batched kernel evaluation, per-class argmax,
//!   hot reload). Its single-threaded core, [`engine::BatchQueue`], is
//!   what [`crate::coordinator::Router`] wraps;
//! * [`server`] — a hand-rolled HTTP/1.1-over-TCP front end exposing
//!   predict / predict-batch / models / reload / stats endpoints;
//! * [`stats`] — batching counters and log-spaced latency histograms,
//!   snapshotted as JSON for `/stats` and `BENCH_serve.json`.
//!
//! End to end: `mlsvm train --registry models --name m` → `mlsvm serve
//! --registry models --model m` → HTTP predictions; `cargo bench --bench
//! serve` drives the closed-loop loadgen against it.

pub mod engine;
pub mod registry;
pub mod server;
pub mod stats;

pub use engine::{BatchQueue, Decision, Engine, EngineConfig, FlushPolicy, FlushReason, Ticket};
pub use registry::{load_artifact, save_artifact, ModelArtifact, Registry};
pub use server::{http_request, http_request_on, ServeState, Server};
pub use stats::{BatchStats, EngineStats, LatencyHistogram, StatsSnapshot};
