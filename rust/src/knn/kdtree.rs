//! Exact KD-tree k-NN. Effective at low dimensionality (≲ 15), which
//! covers several Table-1 data sets (Cod-RNA d=8, Nursery d=8, Letter
//! d=16); higher-dimensional inputs go through the rp-forest instead.

use crate::data::matrix::Matrix;
use crate::knn::{KBest, Neighbor, NeighborLists};
use crate::util::pool;

/// Tree node: either a split or a leaf of point indices.
enum Node {
    Split {
        dim: usize,
        value: f32,
        left: Box<Node>,
        right: Box<Node>,
    },
    Leaf {
        points: Vec<u32>,
    },
}

/// An exact KD-tree over the rows of a matrix.
pub struct KdTree<'a> {
    points: &'a Matrix,
    root: Node,
}

const LEAF_SIZE: usize = 24;

impl<'a> KdTree<'a> {
    /// Build a tree (median splits on the widest dimension).
    pub fn build(points: &'a Matrix) -> KdTree<'a> {
        let mut idx: Vec<u32> = (0..points.rows() as u32).collect();
        let root = Self::build_node(points, &mut idx);
        KdTree { points, root }
    }

    fn build_node(points: &Matrix, idx: &mut [u32]) -> Node {
        if idx.len() <= LEAF_SIZE {
            return Node::Leaf {
                points: idx.to_vec(),
            };
        }
        // Pick the dimension with the widest spread among a sample.
        let d = points.cols();
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        let step = (idx.len() / 64).max(1);
        for &i in idx.iter().step_by(step) {
            for (j, &v) in points.row(i as usize).iter().enumerate() {
                lo[j] = lo[j].min(v);
                hi[j] = hi[j].max(v);
            }
        }
        let dim = (0..d)
            .max_by(|&a, &b| (hi[a] - lo[a]).partial_cmp(&(hi[b] - lo[b])).unwrap())
            .unwrap_or(0);
        // Median split via select_nth_unstable.
        let mid = idx.len() / 2;
        idx.select_nth_unstable_by(mid, |&a, &b| {
            points
                .get(a as usize, dim)
                .partial_cmp(&points.get(b as usize, dim))
                .unwrap()
        });
        let value = points.get(idx[mid] as usize, dim);
        // Guard against degenerate splits (all equal along dim).
        let first = points.get(idx[0] as usize, dim);
        if value == first && points.get(*idx.last().unwrap() as usize, dim) == first {
            return Node::Leaf {
                points: idx.to_vec(),
            };
        }
        let (l, r) = idx.split_at_mut(mid);
        Node::Split {
            dim,
            value,
            left: Box::new(Self::build_node(points, l)),
            right: Box::new(Self::build_node(points, r)),
        }
    }

    /// k nearest neighbors of an arbitrary query vector.
    pub fn knn_query(&self, query: &[f32], k: usize, exclude: Option<u32>) -> Vec<Neighbor> {
        let mut kb = KBest::new(k);
        self.search(&self.root, query, exclude, &mut kb);
        kb.into_sorted()
    }

    fn search(&self, node: &Node, query: &[f32], exclude: Option<u32>, kb: &mut KBest) {
        match node {
            Node::Leaf { points } => {
                for &i in points {
                    if Some(i) == exclude {
                        continue;
                    }
                    let d = crate::data::matrix::sqdist(query, self.points.row(i as usize));
                    if d < kb.worst() {
                        kb.push(d, i);
                    }
                }
            }
            Node::Split {
                dim,
                value,
                left,
                right,
            } => {
                let delta = (query[*dim] - *value) as f64;
                let (near, far) = if delta < 0.0 {
                    (left, right)
                } else {
                    (right, left)
                };
                self.search(near, query, exclude, kb);
                if delta * delta < kb.worst() {
                    self.search(far, query, exclude, kb);
                }
            }
        }
    }

    /// k-NN lists for every indexed point (self excluded).
    pub fn knn_all(&self, k: usize) -> NeighborLists {
        let n = self.points.rows();
        pool::parallel_map(n, 8, |i| {
            self.knn_query(self.points.row(i), k, Some(i as u32))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knn::{brute, recall};
    use crate::util::rng::{Pcg64, Rng};

    fn random_matrix(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seed_from(seed);
        let mut m = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.set(i, j, rng.normal() as f32);
            }
        }
        m
    }

    #[test]
    fn matches_brute_force_exactly() {
        let m = random_matrix(600, 6, 1);
        let tree = KdTree::build(&m);
        let exact = brute::knn(&m, 8);
        let got = tree.knn_all(8);
        assert!(recall(&got, &exact) > 0.9999, "kd-tree must be exact");
    }

    #[test]
    fn handles_duplicate_points() {
        // Many duplicates force degenerate splits.
        let mut data = vec![0.0f32; 200];
        data.extend((0..200).map(|i| (i % 7) as f32));
        let m = Matrix::from_vec(200, 2, data).unwrap();
        let tree = KdTree::build(&m);
        let lists = tree.knn_all(3);
        assert_eq!(lists.len(), 200);
        assert!(lists.iter().all(|l| l.len() == 3));
    }

    #[test]
    fn query_excludes_requested_index() {
        let m = random_matrix(50, 3, 2);
        let tree = KdTree::build(&m);
        let res = tree.knn_query(m.row(7), 5, Some(7));
        assert!(res.iter().all(|n| n.index != 7));
        // nearest neighbor of the point itself without exclusion is itself
        let res2 = tree.knn_query(m.row(7), 1, None);
        assert_eq!(res2[0].index, 7);
    }
}
