//! k-nearest-neighbor search.
//!
//! The paper initializes the multilevel framework with an *approximate*
//! k-NN graph built by FLANN (k = 10, Euclidean), noting that exact graphs
//! change results very little while costing much more. This module is the
//! from-scratch substitute:
//!
//! * [`brute`] — exact O(n²d) search (reference + small inputs);
//! * [`kdtree`] — exact KD-tree search (fast at low dimensionality);
//! * [`rpforest`] — FLANN-like randomized projection-tree forest,
//!   approximate, near-linear build/query time (the default for large n).
//!
//! [`build_knn`] picks a backend automatically and returns per-point
//! neighbor lists that [`crate::graph::affinity`] turns into the AMG
//! affinity graph.

pub mod brute;
pub mod kdtree;
pub mod rpforest;

use crate::data::matrix::Matrix;

/// One neighbor: index + squared Euclidean distance.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Neighbor {
    /// Index of the neighbor point.
    pub index: u32,
    /// Squared Euclidean distance to it.
    pub sqdist: f64,
}

/// k-NN result: `lists[i]` holds up to `k` neighbors of point `i`
/// (self excluded), ascending by distance.
pub type NeighborLists = Vec<Vec<Neighbor>>;

/// Strategy for [`build_knn`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KnnBackend {
    /// Exact O(n²d).
    Brute,
    /// Exact KD-tree.
    KdTree,
    /// Approximate randomized projection forest (FLANN substitute).
    RpForest,
    /// Heuristic: brute for tiny inputs, kd-tree for low dims, rp-forest
    /// otherwise.
    Auto,
}

/// Build k-NN lists for all points with the chosen backend.
///
/// `seed` only matters for the randomized backend.
pub fn build_knn(points: &Matrix, k: usize, backend: KnnBackend, seed: u64) -> NeighborLists {
    let n = points.rows();
    let d = points.cols();
    let backend = match backend {
        KnnBackend::Auto => {
            if n <= 1_500 {
                KnnBackend::Brute
            } else if d <= 12 {
                KnnBackend::KdTree
            } else {
                KnnBackend::RpForest
            }
        }
        b => b,
    };
    match backend {
        KnnBackend::Brute => brute::knn(points, k),
        KnnBackend::KdTree => kdtree::KdTree::build(points).knn_all(k),
        KnnBackend::RpForest => {
            rpforest::RpForest::build(points, rpforest::RpForestParams::default(), seed)
                .knn_all(k)
        }
        KnnBackend::Auto => unreachable!(),
    }
}

/// A bounded max-heap that keeps the k smallest (distance, index) pairs.
/// Shared by all backends.
#[derive(Clone, Debug)]
pub struct KBest {
    k: usize,
    // (sqdist, index), max at front via manual sift on Vec (k is small).
    heap: Vec<(f64, u32)>,
}

impl KBest {
    /// New collector for the k best.
    pub fn new(k: usize) -> KBest {
        KBest {
            k,
            heap: Vec::with_capacity(k + 1),
        }
    }

    /// Current worst (largest) distance kept, or +inf while not full.
    #[inline]
    pub fn worst(&self) -> f64 {
        if self.heap.len() < self.k {
            f64::INFINITY
        } else {
            self.heap[0].0
        }
    }

    /// Number collected so far.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True if nothing collected.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Whether `index` is already collected (linear scan — k is small).
    /// Callers that can produce the same candidate twice (e.g. the
    /// rp-forest, where a pair may share a leaf in several trees) must
    /// check this before pushing, or duplicates will crowd out real
    /// neighbors.
    #[inline]
    pub fn contains(&self, index: u32) -> bool {
        self.heap.iter().any(|&(_, i)| i == index)
    }

    /// Offer a candidate.
    #[inline]
    pub fn push(&mut self, sqdist: f64, index: u32) {
        if self.heap.len() < self.k {
            self.heap.push((sqdist, index));
            // sift up
            let mut i = self.heap.len() - 1;
            while i > 0 {
                let parent = (i - 1) / 2;
                if self.heap[parent].0 < self.heap[i].0 {
                    self.heap.swap(parent, i);
                    i = parent;
                } else {
                    break;
                }
            }
        } else if sqdist < self.heap[0].0 {
            self.heap[0] = (sqdist, index);
            // sift down
            let mut i = 0;
            loop {
                let (l, r) = (2 * i + 1, 2 * i + 2);
                let mut big = i;
                if l < self.heap.len() && self.heap[l].0 > self.heap[big].0 {
                    big = l;
                }
                if r < self.heap.len() && self.heap[r].0 > self.heap[big].0 {
                    big = r;
                }
                if big == i {
                    break;
                }
                self.heap.swap(i, big);
                i = big;
            }
        }
    }

    /// Extract neighbors sorted ascending by distance.
    pub fn into_sorted(self) -> Vec<Neighbor> {
        let mut v: Vec<Neighbor> = self
            .heap
            .into_iter()
            .map(|(d, i)| Neighbor { index: i, sqdist: d })
            .collect();
        v.sort_by(|a, b| a.sqdist.partial_cmp(&b.sqdist).unwrap());
        v
    }
}

/// Recall of approximate lists vs exact lists: fraction of true k-NN
/// recovered (used by tests and the micro bench).
pub fn recall(approx: &NeighborLists, exact: &NeighborLists) -> f64 {
    let mut hit = 0usize;
    let mut total = 0usize;
    for (a, e) in approx.iter().zip(exact) {
        let truth: std::collections::HashSet<u32> = e.iter().map(|n| n.index).collect();
        total += truth.len();
        hit += a.iter().filter(|n| truth.contains(&n.index)).count();
    }
    if total == 0 {
        1.0
    } else {
        hit as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Pcg64, Rng};

    #[test]
    fn kbest_keeps_k_smallest() {
        let mut kb = KBest::new(3);
        for (i, d) in [5.0, 1.0, 4.0, 2.0, 3.0, 0.5].iter().enumerate() {
            kb.push(*d, i as u32);
        }
        let out = kb.into_sorted();
        let dists: Vec<f64> = out.iter().map(|n| n.sqdist).collect();
        assert_eq!(dists, vec![0.5, 1.0, 2.0]);
    }

    #[test]
    fn kbest_worst_tracks_heap_top() {
        let mut kb = KBest::new(2);
        assert_eq!(kb.worst(), f64::INFINITY);
        kb.push(3.0, 0);
        kb.push(1.0, 1);
        assert_eq!(kb.worst(), 3.0);
        kb.push(2.0, 2);
        assert_eq!(kb.worst(), 2.0);
    }

    #[test]
    fn auto_backend_agrees_with_brute_on_small_input() {
        let mut rng = Pcg64::seed_from(5);
        let n = 200;
        let mut m = Matrix::zeros(n, 5);
        for i in 0..n {
            for j in 0..5 {
                m.set(i, j, rng.normal() as f32);
            }
        }
        let auto = build_knn(&m, 5, KnnBackend::Auto, 1);
        let exact = brute::knn(&m, 5);
        assert!(recall(&auto, &exact) > 0.999);
    }
}
