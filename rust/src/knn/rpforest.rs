//! Approximate k-NN via a randomized projection-tree forest — the
//! from-scratch substitute for FLANN [21] used by the paper.
//!
//! Each tree recursively splits the point set with a random hyperplane
//! (Gaussian direction, median threshold with jitter) until leaves are
//! small. Candidate pairs come from co-membership in leaves across all
//! trees; an optional neighbor-of-neighbor refinement pass (NN-descent
//! style) then repairs most remaining misses. Build and graph construction
//! are near O(n log n · d) — versus O(n² d) exact — and the paper reports
//! that graph approximation does not measurably change classifier quality
//! (we verify ≥0.9 recall on Gaussian data in tests; the AMG coarsening is
//! robust to the remainder).
//!
//! Both phases run over [`crate::util::pool`]: trees grow independently
//! from per-tree seeded RNGs (the forest itself is schedule-independent),
//! and candidate generation distributes leaves (then refinement points)
//! across the workers, updating per-point best-lists behind fine-grained
//! mutexes. Graph build dominates coarsening wall-clock on large sets,
//! and both phases are embarrassingly parallel up to those list updates.
//! Caveat: when several candidates are exactly equidistant (e.g.
//! duplicate points), which of them survives a full best-list depends on
//! worker arrival order, so `knn_all` is deterministic only up to
//! distance ties — the same approximation the paper already accepts from
//! FLANN, and the AMG coarsening is robust to it.

use crate::data::matrix::Matrix;
use crate::knn::{KBest, Neighbor, NeighborLists};
use crate::util::pool;
use crate::util::rng::{Pcg64, Rng};
use std::sync::Mutex;

/// Forest parameters.
#[derive(Clone, Copy, Debug)]
pub struct RpForestParams {
    /// Number of trees (more trees → higher recall, linear cost).
    pub n_trees: usize,
    /// Maximum leaf size (pairs within a leaf become candidates).
    pub leaf_size: usize,
    /// Neighbor-of-neighbor refinement sweeps after the forest pass.
    pub refine_iters: usize,
}

impl Default for RpForestParams {
    fn default() -> Self {
        RpForestParams {
            n_trees: 8,
            leaf_size: 32,
            refine_iters: 1,
        }
    }
}

enum Node {
    Split {
        dir: Vec<f32>,
        thresh: f32,
        left: Box<Node>,
        right: Box<Node>,
    },
    Leaf {
        points: Vec<u32>,
    },
}

/// A built forest over the rows of a matrix.
pub struct RpForest<'a> {
    points: &'a Matrix,
    trees: Vec<Node>,
    params: RpForestParams,
}

fn project(dir: &[f32], row: &[f32]) -> f32 {
    crate::data::matrix::dot(dir, row)
}

impl<'a> RpForest<'a> {
    /// Build `params.n_trees` random projection trees, in parallel over
    /// the [`crate::util::pool`] workers. Each tree draws from its own
    /// deterministically-seeded RNG, so the forest does not depend on how
    /// trees were scheduled.
    pub fn build(points: &'a Matrix, params: RpForestParams, seed: u64) -> RpForest<'a> {
        let base = seed ^ 0x9e37_79b9_7f4a_7c15;
        let trees = pool::parallel_gen(params.n_trees, |t| {
            let mut rng =
                Pcg64::seed_from(base.wrapping_add((t as u64).wrapping_mul(0x2545_f491_4f6c_dd1d)));
            let mut idx: Vec<u32> = (0..points.rows() as u32).collect();
            Self::build_node(points, &mut idx, params.leaf_size, &mut rng, 0)
        });
        RpForest {
            points,
            trees,
            params,
        }
    }

    fn build_node(
        points: &Matrix,
        idx: &mut Vec<u32>,
        leaf_size: usize,
        rng: &mut Pcg64,
        depth: usize,
    ) -> Node {
        if idx.len() <= leaf_size || depth > 40 {
            return Node::Leaf {
                points: std::mem::take(idx),
            };
        }
        let d = points.cols();
        let mut dir: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let norm = dir.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
        dir.iter_mut().for_each(|x| *x /= norm);
        let mut projs: Vec<f32> = idx
            .iter()
            .map(|&i| project(&dir, points.row(i as usize)))
            .collect();
        // Median threshold with ±5% jitter for tree diversity.
        let mid = projs.len() / 2;
        let (_, &mut median, _) =
            projs.select_nth_unstable_by(mid, |a, b| a.partial_cmp(b).unwrap());
        let spread = {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &p in projs.iter() {
                lo = lo.min(p);
                hi = hi.max(p);
            }
            hi - lo
        };
        let thresh = median + (rng.f32() - 0.5) * 0.1 * spread;
        let mut left_idx = Vec::with_capacity(mid + 1);
        let mut right_idx = Vec::with_capacity(mid + 1);
        for &i in idx.iter() {
            if project(&dir, points.row(i as usize)) < thresh {
                left_idx.push(i);
            } else {
                right_idx.push(i);
            }
        }
        // Degenerate split (identical projections): make a leaf.
        if left_idx.is_empty() || right_idx.is_empty() {
            return Node::Leaf {
                points: std::mem::take(idx),
            };
        }
        idx.clear();
        idx.shrink_to_fit();
        Node::Split {
            dir,
            thresh,
            left: Box::new(Self::build_node(points, &mut left_idx, leaf_size, rng, depth + 1)),
            right: Box::new(Self::build_node(points, &mut right_idx, leaf_size, rng, depth + 1)),
        }
    }

    fn leaves<'n>(node: &'n Node, out: &mut Vec<&'n [u32]>) {
        match node {
            Node::Leaf { points } => out.push(points),
            Node::Split { left, right, .. } => {
                Self::leaves(left, out);
                Self::leaves(right, out);
            }
        }
    }

    /// Approximate k-NN lists for all points. Candidate generation is
    /// parallel: leaves (phase 1) and points (phase 2) are distributed
    /// over the pool workers, and the two sides of each candidate pair
    /// are offered under their own per-point locks (never held together,
    /// so no lock-order deadlock is possible). Racing offers of the same
    /// pair are harmless: the final sort+dedup pass removes duplicates.
    pub fn knn_all(&self, k: usize) -> NeighborLists {
        let n = self.points.rows();
        let best: Vec<Mutex<KBest>> = (0..n).map(|_| Mutex::new(KBest::new(k))).collect();
        let offer = |target: usize, d: f64, idx: u32| {
            let mut kb = best[target].lock().unwrap();
            if d < kb.worst() && !kb.contains(idx) {
                kb.push(d, idx);
            }
        };

        // Phase 1: all pairs within each leaf of each tree, parallel over
        // the leaves of the whole forest.
        let mut leaves: Vec<&[u32]> = Vec::new();
        for tree in &self.trees {
            Self::leaves(tree, &mut leaves);
        }
        pool::parallel_for(leaves.len(), 4, |li| {
            let leaf = leaves[li];
            for (a_pos, &a) in leaf.iter().enumerate() {
                let ra = self.points.row(a as usize);
                for &b in leaf.iter().skip(a_pos + 1) {
                    let d = crate::data::matrix::sqdist(ra, self.points.row(b as usize));
                    offer(a as usize, d, b);
                    offer(b as usize, d, a);
                }
            }
        });

        // Phase 2: neighbor-of-neighbor refinement (NN-descent lite),
        // parallel over points against a frozen snapshot of the lists.
        for _ in 0..self.params.refine_iters {
            let snapshot: Vec<Vec<u32>> = best
                .iter()
                .map(|kb| {
                    let kb = kb.lock().unwrap().clone();
                    kb.into_sorted().iter().map(|n| n.index).collect()
                })
                .collect();
            pool::parallel_for(n, 8, |i| {
                let ri = self.points.row(i);
                for &j in &snapshot[i] {
                    for &l in &snapshot[j as usize] {
                        if l as usize == i {
                            continue;
                        }
                        let d = crate::data::matrix::sqdist(ri, self.points.row(l as usize));
                        offer(i, d, l);
                        offer(l as usize, d, i as u32);
                    }
                }
            });
        }

        best.into_iter()
            .map(|kb| {
                // Deduplicate (a pair can surface in several trees, or be
                // offered twice by racing workers).
                let mut v = kb.into_inner().unwrap().into_sorted();
                v.dedup_by_key(|n| n.index);
                v.truncate(k);
                v
            })
            .collect()
    }

    /// Approximate k-NN of an arbitrary query: descend each tree, brute
    /// force over the union of reached leaves.
    pub fn knn_query(&self, query: &[f32], k: usize) -> Vec<Neighbor> {
        let mut kb = KBest::new(k);
        let mut seen = std::collections::HashSet::new();
        for tree in &self.trees {
            let mut node = tree;
            loop {
                match node {
                    Node::Leaf { points } => {
                        for &i in points {
                            if seen.insert(i) {
                                let d =
                                    crate::data::matrix::sqdist(query, self.points.row(i as usize));
                                if d < kb.worst() {
                                    kb.push(d, i);
                                }
                            }
                        }
                        break;
                    }
                    Node::Split {
                        dir,
                        thresh,
                        left,
                        right,
                    } => {
                        node = if project(dir, query) < *thresh {
                            left
                        } else {
                            right
                        };
                    }
                }
            }
        }
        kb.into_sorted()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knn::{brute, recall};

    fn gaussian_clusters(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seed_from(seed);
        let mut m = Matrix::zeros(n, d);
        for i in 0..n {
            let c = (i % 5) as f64 * 4.0;
            for j in 0..d {
                m.set(i, j, (c + rng.normal()) as f32);
            }
        }
        m
    }

    #[test]
    fn recall_is_high_on_clustered_data() {
        let m = gaussian_clusters(1200, 16, 3);
        let forest = RpForest::build(&m, RpForestParams::default(), 7);
        let approx = forest.knn_all(10);
        let exact = brute::knn(&m, 10);
        let r = recall(&approx, &exact);
        assert!(r > 0.9, "recall={r}");
    }

    #[test]
    fn lists_are_sorted_self_free_and_unique() {
        let m = gaussian_clusters(400, 8, 4);
        let forest = RpForest::build(&m, RpForestParams::default(), 1);
        let lists = forest.knn_all(6);
        for (i, l) in lists.iter().enumerate() {
            assert!(l.iter().all(|n| n.index as usize != i), "self loop at {i}");
            for w in l.windows(2) {
                assert!(w[0].sqdist <= w[1].sqdist);
                assert_ne!(w[0].index, w[1].index);
            }
        }
    }

    #[test]
    fn query_returns_near_points() {
        let m = gaussian_clusters(500, 8, 5);
        let forest = RpForest::build(&m, RpForestParams::default(), 2);
        let res = forest.knn_query(m.row(42), 3);
        assert_eq!(res[0].index, 42, "nearest to a data point is itself");
    }

    #[test]
    fn duplicate_points_terminate() {
        let m = Matrix::from_vec(300, 2, vec![1.0; 600]).unwrap();
        let forest = RpForest::build(&m, RpForestParams::default(), 3);
        let lists = forest.knn_all(4);
        assert_eq!(lists.len(), 300);
    }
}
