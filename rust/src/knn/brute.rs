//! Exact O(n²·d) k-NN. Reference implementation for correctness tests and
//! the right choice for small inputs (coarse AMG levels are small, so this
//! also serves the hierarchy once levels shrink below a few thousand).

use crate::data::matrix::Matrix;
use crate::knn::{KBest, NeighborLists};
use crate::util::pool;

/// Exact k-NN lists for every row of `points` (self excluded).
pub fn knn(points: &Matrix, k: usize) -> NeighborLists {
    let n = points.rows();
    pool::parallel_map(n, 8, |i| {
        let mut kb = KBest::new(k);
        let a = points.row(i);
        for j in 0..n {
            if j == i {
                continue;
            }
            let d = crate::data::matrix::sqdist(a, points.row(j));
            if d < kb.worst() {
                kb.push(d, j as u32);
            }
        }
        kb.into_sorted()
    })
}

/// Exact k-NN of `queries` rows against `data` rows (no self-exclusion).
pub fn knn_queries(data: &Matrix, queries: &Matrix, k: usize) -> NeighborLists {
    let nq = queries.rows();
    pool::parallel_map(nq, 8, |q| {
        let mut kb = KBest::new(k);
        let a = queries.row(q);
        for j in 0..data.rows() {
            let d = crate::data::matrix::sqdist(a, data.row(j));
            if d < kb.worst() {
                kb.push(d, j as u32);
            }
        }
        kb.into_sorted()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knn_on_line_graph() {
        // points at x = 0, 1, 2, 3: neighbors of 1 are {0, 2} for k=2.
        let m = Matrix::from_vec(4, 1, vec![0., 1., 2., 3.]).unwrap();
        let lists = knn(&m, 2);
        let idx: Vec<u32> = lists[1].iter().map(|n| n.index).collect();
        assert!(idx.contains(&0) && idx.contains(&2));
        // endpoint 0: neighbors {1, 2}
        let idx0: Vec<u32> = lists[0].iter().map(|n| n.index).collect();
        assert_eq!(idx0, vec![1, 2]);
    }

    #[test]
    fn excludes_self_and_sorts() {
        let m = Matrix::from_vec(3, 1, vec![0., 10., 11.]).unwrap();
        let lists = knn(&m, 2);
        for (i, l) in lists.iter().enumerate() {
            assert!(l.iter().all(|n| n.index as usize != i));
            for w in l.windows(2) {
                assert!(w[0].sqdist <= w[1].sqdist);
            }
        }
    }

    #[test]
    fn k_larger_than_n_returns_all_others() {
        let m = Matrix::from_vec(3, 1, vec![0., 1., 2.]).unwrap();
        let lists = knn(&m, 10);
        assert!(lists.iter().all(|l| l.len() == 2));
    }

    #[test]
    fn queries_against_data() {
        let data = Matrix::from_vec(3, 1, vec![0., 5., 10.]).unwrap();
        let q = Matrix::from_vec(1, 1, vec![6.]).unwrap();
        let lists = knn_queries(&data, &q, 2);
        assert_eq!(lists[0][0].index, 1);
        assert_eq!(lists[0][1].index, 2);
    }
}
