//! One coarsening step: fine level → coarse level.
//!
//! Given the fine graph, points and volumes, this module runs seed
//! selection (Algorithm 1), builds the interpolation operator P (Eq. 4)
//! and produces the coarse training set:
//!
//! * coarse volume  `v_c(q) = Σ_j v_j P_{jq}` — total volume is conserved;
//! * coarse point   `x_c(q) = Σ_j v_j P_{jq} x_j / v_c(q)` — the
//!   volume-weighted centroid of the (fractional) aggregate. (The paper
//!   prints the unnormalized sum but describes the coarse points as
//!   *centroids* of aggregates; the normalized form is the one that keeps
//!   coarse points on the data manifold, and matches the reference
//!   implementation.)
//! * coarse edges   `W_c = PᵀWP` with the diagonal dropped (Galerkin).

use crate::amg::interp::{interpolation, InterpParams, Interpolation};
use crate::amg::seeds::{select_seeds, SeedParams};
use crate::data::matrix::Matrix;
use crate::error::Result;
use crate::graph::csr::{CsrGraph, SparseRowMatrix};

/// Output of one coarsening step.
#[derive(Debug)]
pub struct CoarseLevel {
    /// Coarse data points (volume-weighted aggregate centroids).
    pub points: Matrix,
    /// Coarse volumes.
    pub volumes: Vec<f64>,
    /// Coarse affinity graph.
    pub graph: CsrGraph,
    /// Interpolation operator from the fine level (n_f × n_c).
    pub p: SparseRowMatrix,
    /// Fine seed index of each coarse node.
    pub seed_of_coarse: Vec<u32>,
    /// Aggregate membership: `aggregates[q]` lists fine nodes with
    /// P[j,q] > 0 (the I⁻¹(q) of Algorithm 3).
    pub aggregates: Vec<Vec<u32>>,
}

/// Parameters for one coarsening step.
#[derive(Clone, Copy, Debug, Default)]
pub struct CoarsenParams {
    /// Algorithm-1 parameters (Q, η).
    pub seed: SeedParams,
    /// Interpolation caliber R.
    pub interp: InterpParams,
}

/// Coarsen one level.
pub fn coarsen_level(
    points: &Matrix,
    volumes: &[f64],
    graph: &CsrGraph,
    params: CoarsenParams,
) -> Result<CoarseLevel> {
    let is_seed = select_seeds(graph, volumes, params.seed);
    let Interpolation {
        p,
        seed_of_coarse,
        ..
    } = interpolation(graph, &is_seed, params.interp);
    let nc = seed_of_coarse.len();
    let nf = points.rows();
    let d = points.cols();

    // Coarse volumes and volume-weighted centroid accumulation.
    let mut cvol = vec![0.0f64; nc];
    let mut acc = vec![0.0f64; nc * d];
    for j in 0..nf {
        let vj = volumes[j];
        let row = points.row(j);
        for &(q, pjq) in p.row(j) {
            let wq = vj * pjq as f64;
            cvol[q as usize] += wq;
            let dst = &mut acc[q as usize * d..(q as usize + 1) * d];
            for (a, &x) in dst.iter_mut().zip(row) {
                *a += wq * x as f64;
            }
        }
    }
    let mut cpoints = Matrix::zeros(nc, d);
    for q in 0..nc {
        let v = cvol[q].max(1e-300);
        let dst = cpoints.row_mut(q);
        for (x, &a) in dst.iter_mut().zip(&acc[q * d..(q + 1) * d]) {
            *x = (a / v) as f32;
        }
    }

    // Aggregates (I⁻¹).
    let mut aggregates: Vec<Vec<u32>> = vec![Vec::new(); nc];
    for j in 0..nf {
        for &(q, pjq) in p.row(j) {
            if pjq > 0.0 {
                aggregates[q as usize].push(j as u32);
            }
        }
    }

    let cgraph = graph.galerkin(&p)?;
    Ok(CoarseLevel {
        points: cpoints,
        volumes: cvol,
        graph: cgraph,
        p,
        seed_of_coarse,
        aggregates,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::affinity::affinity_graph;
    use crate::knn::KnnBackend;
    use crate::util::rng::{Pcg64, Rng};

    fn random_blob(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seed_from(seed);
        let mut m = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                // two clusters
                let c = if i % 2 == 0 { 0.0 } else { 6.0 };
                m.set(i, j, (c + rng.normal()) as f32);
            }
        }
        m
    }

    #[test]
    fn total_volume_is_conserved() {
        let pts = random_blob(400, 4, 21);
        let mut rng = Pcg64::seed_from(3);
        let volumes: Vec<f64> = (0..400).map(|_| 0.5 + rng.f64()).collect();
        let g = affinity_graph(&pts, 8, KnnBackend::Brute, 0).unwrap();
        let cl = coarsen_level(&pts, &volumes, &g, CoarsenParams::default()).unwrap();
        let fine: f64 = volumes.iter().sum();
        let coarse: f64 = cl.volumes.iter().sum();
        assert!(
            (fine - coarse).abs() < 1e-9 * fine,
            "volume {fine} -> {coarse}"
        );
    }

    #[test]
    fn coarse_level_is_smaller() {
        let pts = random_blob(500, 4, 22);
        let g = affinity_graph(&pts, 10, KnnBackend::Brute, 0).unwrap();
        let cl = coarsen_level(&pts, &vec![1.0; 500], &g, CoarsenParams::default()).unwrap();
        assert!(cl.points.rows() < 500, "no reduction");
        assert!(cl.points.rows() > 10, "overcollapse");
        cl.graph.validate().unwrap();
    }

    #[test]
    fn centroids_stay_inside_data_bounding_box() {
        let pts = random_blob(300, 3, 23);
        let g = affinity_graph(&pts, 6, KnnBackend::Brute, 0).unwrap();
        let cl = coarsen_level(&pts, &vec![1.0; 300], &g, CoarsenParams::default()).unwrap();
        for j in 0..3 {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for i in 0..300 {
                lo = lo.min(pts.get(i, j));
                hi = hi.max(pts.get(i, j));
            }
            for q in 0..cl.points.rows() {
                let v = cl.points.get(q, j);
                assert!(v >= lo - 1e-4 && v <= hi + 1e-4, "centroid escaped box");
            }
        }
    }

    #[test]
    fn aggregates_cover_all_fine_points() {
        let pts = random_blob(250, 4, 24);
        let g = affinity_graph(&pts, 8, KnnBackend::Brute, 0).unwrap();
        let cl = coarsen_level(&pts, &vec![1.0; 250], &g, CoarsenParams::default()).unwrap();
        let mut covered = vec![false; 250];
        for agg in &cl.aggregates {
            for &j in agg {
                covered[j as usize] = true;
            }
        }
        assert!(covered.iter().all(|&c| c), "a fine point is in no aggregate");
    }

    #[test]
    fn seed_points_become_their_own_centroid_under_caliber_1() {
        // With hard aggregation, each aggregate centroid is the mean of its
        // members; the seed is a member of its own aggregate.
        let pts = random_blob(200, 3, 25);
        let g = affinity_graph(&pts, 6, KnnBackend::Brute, 0).unwrap();
        let params = CoarsenParams {
            interp: InterpParams { caliber: 1 },
            ..Default::default()
        };
        let cl = coarsen_level(&pts, &vec![1.0; 200], &g, params).unwrap();
        for (q, &s) in cl.seed_of_coarse.iter().enumerate() {
            assert!(
                cl.aggregates[q].contains(&s),
                "seed {s} not in its own aggregate {q}"
            );
        }
    }
}
