//! Future volumes (Eq. 3):
//!
//! ```text
//! ϑ_i = v_i + Σ_{j ∈ F} v_j · w_ji / Σ_{k ∈ V} w_jk
//! ```
//!
//! ϑ_i measures how much an aggregate seeded at `i` could grow: every
//! still-free node `j` donates its volume to its neighbors proportionally
//! to relative edge weight. Nodes with large ϑ are prime seed candidates.

use crate::graph::csr::CsrGraph;

/// Compute ϑ for every node. `free[j]` marks membership in F (donors);
/// ϑ is *reported* for all nodes but only F-nodes donate volume.
///
/// An isolated free node contributes nothing and keeps ϑ_i = v_i.
pub fn future_volumes(graph: &CsrGraph, volumes: &[f64], free: &[bool]) -> Vec<f64> {
    let n = graph.n();
    debug_assert_eq!(volumes.len(), n);
    debug_assert_eq!(free.len(), n);
    let mut theta: Vec<f64> = volumes.to_vec();
    for j in 0..n {
        if !free[j] {
            continue;
        }
        let (idx, w) = graph.row(j);
        let total: f64 = w.iter().sum();
        if total <= 0.0 {
            continue;
        }
        let scale = volumes[j] / total;
        for (&i, &wji) in idx.iter().zip(w) {
            theta[i as usize] += scale * wji;
        }
    }
    theta
}

/// Mean of ϑ restricted to the free set (Algorithm 1 line 2 uses the
/// average over the candidates).
pub fn mean_over(theta: &[f64], free: &[bool]) -> f64 {
    let mut sum = 0.0;
    let mut cnt = 0usize;
    for (t, &f) in theta.iter().zip(free) {
        if f {
            sum += t;
            cnt += 1;
        }
    }
    if cnt == 0 {
        0.0
    } else {
        sum / cnt as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Star: center 0 connected to 1,2,3 with unit weights.
    fn star() -> CsrGraph {
        CsrGraph::from_edges(4, &[(0, 1, 1.0), (0, 2, 1.0), (0, 3, 1.0)]).unwrap()
    }

    #[test]
    fn star_center_accumulates() {
        let g = star();
        let v = vec![1.0; 4];
        let free = vec![true; 4];
        let theta = future_volumes(&g, &v, &free);
        // Each leaf donates all of its volume to the center: ϑ_0 = 1 + 3.
        assert!((theta[0] - 4.0).abs() < 1e-12);
        // Center donates 1/3 to each leaf: ϑ_leaf = 1 + 1/3.
        for i in 1..4 {
            assert!((theta[i] - (1.0 + 1.0 / 3.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn non_free_nodes_do_not_donate() {
        let g = star();
        let v = vec![1.0; 4];
        let mut free = vec![true; 4];
        free[1] = false; // node 1 no longer donates
        let theta = future_volumes(&g, &v, &free);
        assert!((theta[0] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn volume_donation_is_proportional_to_weight() {
        // 0-1 weight 3, 0-2 weight 1: node 0 donates 3/4 to 1, 1/4 to 2.
        let g = CsrGraph::from_edges(3, &[(0, 1, 3.0), (0, 2, 1.0)]).unwrap();
        let theta = future_volumes(&g, &[1.0; 3], &[true; 3]);
        assert!((theta[1] - (1.0 + 0.75 + 0.0)).abs() < 1e-12); // from 0 only
        assert!((theta[2] - (1.0 + 0.25)).abs() < 1e-12);
    }

    #[test]
    fn isolated_node_keeps_own_volume() {
        let g = CsrGraph::from_edges(3, &[(0, 1, 1.0)]).unwrap();
        let theta = future_volumes(&g, &[1.0, 1.0, 7.0], &[true; 3]);
        assert_eq!(theta[2], 7.0);
    }

    #[test]
    fn mean_over_free_subset() {
        let theta = [1.0, 100.0, 3.0];
        assert!((mean_over(&theta, &[true, false, true]) - 2.0).abs() < 1e-12);
        assert_eq!(mean_over(&theta, &[false, false, false]), 0.0);
    }
}
