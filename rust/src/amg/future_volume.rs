//! Future volumes (Eq. 3):
//!
//! ```text
//! ϑ_i = v_i + Σ_{j ∈ F} v_j · w_ji / Σ_{k ∈ V} w_jk
//! ```
//!
//! ϑ_i measures how much an aggregate seeded at `i` could grow: every
//! still-free node `j` donates its volume to its neighbors proportionally
//! to relative edge weight. Nodes with large ϑ are prime seed candidates.

use crate::graph::csr::CsrGraph;
use crate::util::pool;

/// Nodes per parallel task in the two passes below (each node is O(degree)
/// work — k-NN graphs have small, even degrees, so large chunks amortize
/// the scheduling).
const CHUNK: usize = 512;

/// Compute ϑ for every node. `free[j]` marks membership in F (donors);
/// ϑ is *reported* for all nodes but only F-nodes donate volume.
///
/// An isolated free node contributes nothing and keeps ϑ_i = v_i.
///
/// Runs as two data-parallel gather passes over [`crate::util::pool`]
/// instead of the textbook donor *scatter*: pass 1 precomputes each free
/// donor's per-unit-weight donation `v_j / Σ_k w_jk`, pass 2 gathers each
/// node's ϑ from its own neighbor list. Because the graph is symmetric
/// (`w_ij = w_ji`) and CSR rows are sorted by column, pass 2 accumulates
/// exactly the same terms in exactly the same (ascending-j) order as the
/// scatter loop did — the result is bit-identical to the sequential
/// version at any thread count.
pub fn future_volumes(graph: &CsrGraph, volumes: &[f64], free: &[bool]) -> Vec<f64> {
    let n = graph.n();
    debug_assert_eq!(volumes.len(), n);
    debug_assert_eq!(free.len(), n);
    // Pass 1: donation per unit of edge weight for every free donor
    // (0 for held nodes and isolated donors — adding 0·w leaves ϑ's bits
    // unchanged, volumes are non-negative).
    let scale = pool::parallel_map(n, CHUNK, |j| {
        if !free[j] {
            return 0.0;
        }
        let total: f64 = graph.row(j).1.iter().sum();
        if total <= 0.0 {
            0.0
        } else {
            volumes[j] / total
        }
    });
    // Pass 2: gather ϑ_i = v_i + Σ_{j ∈ N(i)} scale_j · w_ij.
    pool::parallel_map(n, CHUNK, |i| {
        let (idx, w) = graph.row(i);
        let mut theta = volumes[i];
        for (&j, &wij) in idx.iter().zip(w) {
            theta += scale[j as usize] * wij;
        }
        theta
    })
}

/// Mean of ϑ restricted to the free set (Algorithm 1 line 2 uses the
/// average over the candidates).
pub fn mean_over(theta: &[f64], free: &[bool]) -> f64 {
    let mut sum = 0.0;
    let mut cnt = 0usize;
    for (t, &f) in theta.iter().zip(free) {
        if f {
            sum += t;
            cnt += 1;
        }
    }
    if cnt == 0 {
        0.0
    } else {
        sum / cnt as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Star: center 0 connected to 1,2,3 with unit weights.
    fn star() -> CsrGraph {
        CsrGraph::from_edges(4, &[(0, 1, 1.0), (0, 2, 1.0), (0, 3, 1.0)]).unwrap()
    }

    #[test]
    fn star_center_accumulates() {
        let g = star();
        let v = vec![1.0; 4];
        let free = vec![true; 4];
        let theta = future_volumes(&g, &v, &free);
        // Each leaf donates all of its volume to the center: ϑ_0 = 1 + 3.
        assert!((theta[0] - 4.0).abs() < 1e-12);
        // Center donates 1/3 to each leaf: ϑ_leaf = 1 + 1/3.
        for i in 1..4 {
            assert!((theta[i] - (1.0 + 1.0 / 3.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn non_free_nodes_do_not_donate() {
        let g = star();
        let v = vec![1.0; 4];
        let mut free = vec![true; 4];
        free[1] = false; // node 1 no longer donates
        let theta = future_volumes(&g, &v, &free);
        assert!((theta[0] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn volume_donation_is_proportional_to_weight() {
        // 0-1 weight 3, 0-2 weight 1: node 0 donates 3/4 to 1, 1/4 to 2.
        let g = CsrGraph::from_edges(3, &[(0, 1, 3.0), (0, 2, 1.0)]).unwrap();
        let theta = future_volumes(&g, &[1.0; 3], &[true; 3]);
        assert!((theta[1] - (1.0 + 0.75 + 0.0)).abs() < 1e-12); // from 0 only
        assert!((theta[2] - (1.0 + 0.25)).abs() < 1e-12);
    }

    #[test]
    fn isolated_node_keeps_own_volume() {
        let g = CsrGraph::from_edges(3, &[(0, 1, 1.0)]).unwrap();
        let theta = future_volumes(&g, &[1.0, 1.0, 7.0], &[true; 3]);
        assert_eq!(theta[2], 7.0);
    }

    #[test]
    fn gather_matches_reference_scatter_bitwise() {
        // The textbook donor-scatter loop the parallel gather replaced.
        fn scatter(graph: &CsrGraph, volumes: &[f64], free: &[bool]) -> Vec<f64> {
            let mut theta: Vec<f64> = volumes.to_vec();
            for j in 0..graph.n() {
                if !free[j] {
                    continue;
                }
                let (idx, w) = graph.row(j);
                let total: f64 = w.iter().sum();
                if total <= 0.0 {
                    continue;
                }
                let scale = volumes[j] / total;
                for (&i, &wji) in idx.iter().zip(w) {
                    theta[i as usize] += scale * wji;
                }
            }
            theta
        }
        use crate::util::rng::{Pcg64, Rng};
        let mut rng = Pcg64::seed_from(21);
        let n = 400;
        let mut edges = Vec::new();
        for i in 0..n as u32 {
            for _ in 0..5 {
                let j = rng.index(n) as u32;
                if j != i {
                    edges.push((i, j, 0.05 + rng.f64()));
                }
            }
        }
        let g = CsrGraph::from_edges(n, &edges).unwrap();
        let volumes: Vec<f64> = (0..n).map(|_| 0.5 + rng.f64()).collect();
        let free: Vec<bool> = (0..n).map(|i| i % 7 != 0).collect();
        let want = scatter(&g, &volumes, &free);
        let got = future_volumes(&g, &volumes, &free);
        assert_eq!(want, got, "gather must be bit-identical to scatter");
    }

    #[test]
    fn mean_over_free_subset() {
        let theta = [1.0, 100.0, 3.0];
        assert!((mean_over(&theta, &[true, false, true]) - 2.0).abs() < 1e-12);
        assert_eq!(mean_over(&theta, &[false, false, false]), 0.0);
    }
}
