//! Seed selection — Algorithm 1 of the paper.
//!
//! Seeds C ⊂ V_f become the centers of coarse aggregates. The algorithm:
//!
//! 1. C ← ∅, F ← V_f; compute future volumes ϑ (Eq. 3);
//! 2. transfer nodes with ϑ_i > η·mean(ϑ) to C ("exceptionally large");
//! 3. recompute ϑ over the remaining F;
//! 4. visit F in decreasing ϑ order; move `i` to C when its coupling to
//!    the current C is weak: Σ_{j∈C} w_ij / Σ_{j∈V} w_ij ≤ Q.
//!
//! Paper defaults: Q = 0.5, η = 2.

use crate::amg::future_volume::{future_volumes, mean_over};
use crate::graph::csr::CsrGraph;

/// Parameters of Algorithm 1.
#[derive(Clone, Copy, Debug)]
pub struct SeedParams {
    /// Coupling threshold Q: an F-node stays in F only if more than Q of
    /// its total edge weight already points at seeds.
    pub q: f64,
    /// Future-volume outlier factor η.
    pub eta: f64,
}

impl Default for SeedParams {
    fn default() -> Self {
        SeedParams { q: 0.5, eta: 2.0 }
    }
}

/// Run Algorithm 1. Returns `is_seed` per node. Isolated nodes (no edges)
/// always become seeds (their coupling ratio is 0 ≤ Q).
pub fn select_seeds(graph: &CsrGraph, volumes: &[f64], params: SeedParams) -> Vec<bool> {
    let n = graph.n();
    let mut is_seed = vec![false; n];
    if n == 0 {
        return is_seed;
    }
    // Lines 1-2: all free, initial future volumes.
    let mut free = vec![true; n];
    let theta = future_volumes(graph, volumes, &free);
    let mean = mean_over(&theta, &free);

    // Line 3: exceptionally large future volumes seed immediately.
    for i in 0..n {
        if theta[i] > params.eta * mean {
            is_seed[i] = true;
            free[i] = false;
        }
    }

    // Line 5: recompute ϑ over the remaining F.
    let theta = future_volumes(graph, volumes, &free);

    // Line 6: visit F in decreasing ϑ.
    let mut order: Vec<usize> = (0..n).filter(|&i| free[i]).collect();
    order.sort_by(|&a, &b| theta[b].partial_cmp(&theta[a]).unwrap());

    // Lines 7-11.
    for i in order {
        let (idx, w) = graph.row(i);
        let total: f64 = w.iter().sum();
        let to_seeds: f64 = idx
            .iter()
            .zip(w)
            .filter(|(&j, _)| is_seed[j as usize])
            .map(|(_, &wij)| wij)
            .sum();
        let ratio = if total > 0.0 { to_seeds / total } else { 0.0 };
        if ratio <= params.q {
            is_seed[i] = true;
            free[i] = false;
        }
    }
    is_seed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Pcg64, Rng};

    #[test]
    fn star_center_becomes_seed_leaves_do_not() {
        // Center of a big star has outlier future volume.
        let mut edges = Vec::new();
        for leaf in 1..=10u32 {
            edges.push((0u32, leaf, 1.0));
        }
        let g = CsrGraph::from_edges(11, &edges).unwrap();
        let seeds = select_seeds(&g, &vec![1.0; 11], SeedParams::default());
        assert!(seeds[0], "hub must seed");
        // All leaves are fully coupled to the hub (ratio 1 > Q): stay in F.
        for leaf in 1..11 {
            assert!(!seeds[leaf], "leaf {leaf} must not seed");
        }
    }

    #[test]
    fn every_f_node_is_coupled_to_seeds_above_q() {
        // Invariant used by interpolation: any non-seed has > Q of its
        // weight on seeds.
        let mut rng = Pcg64::seed_from(8);
        let n = 300;
        let mut edges = Vec::new();
        for i in 0..n as u32 {
            for _ in 0..6 {
                let j = rng.index(n) as u32;
                if j != i {
                    edges.push((i, j, 0.1 + rng.f64()));
                }
            }
        }
        let g = CsrGraph::from_edges(n, &edges).unwrap();
        let params = SeedParams::default();
        let seeds = select_seeds(&g, &vec![1.0; n], params);
        for i in 0..n {
            if seeds[i] {
                continue;
            }
            let (idx, w) = g.row(i);
            let total: f64 = w.iter().sum();
            let to_seeds: f64 = idx
                .iter()
                .zip(w)
                .filter(|(&j, _)| seeds[j as usize])
                .map(|(_, &wij)| wij)
                .sum();
            assert!(
                to_seeds / total > params.q,
                "node {i} left in F but coupling {}",
                to_seeds / total
            );
        }
    }

    #[test]
    fn isolated_nodes_become_seeds() {
        let g = CsrGraph::from_edges(3, &[(0, 1, 1.0)]).unwrap();
        let seeds = select_seeds(&g, &[1.0; 3], SeedParams::default());
        assert!(seeds[2], "isolated node must seed");
    }

    #[test]
    fn seeds_shrink_the_set_but_not_to_zero() {
        let mut rng = Pcg64::seed_from(9);
        let n = 500;
        let mut edges = Vec::new();
        // ring + random chords: well-connected graph
        for i in 0..n as u32 {
            edges.push((i, (i + 1) % n as u32, 1.0));
            let j = rng.index(n) as u32;
            if j != i {
                edges.push((i, j, 0.5));
            }
        }
        let g = CsrGraph::from_edges(n, &edges).unwrap();
        let seeds = select_seeds(&g, &vec![1.0; n], SeedParams::default());
        let c = seeds.iter().filter(|&&s| s).count();
        assert!(c > 0, "no seeds selected");
        assert!(c < n, "everything became a seed");
        // AMG-style coarsening should at least halve a well-connected graph
        // ... loosely: require < 90%.
        assert!(c < n * 9 / 10, "c={c}");
    }

    #[test]
    fn higher_q_selects_more_seeds() {
        let mut rng = Pcg64::seed_from(10);
        let n = 400;
        let mut edges = Vec::new();
        for i in 0..n as u32 {
            for _ in 0..5 {
                let j = rng.index(n) as u32;
                if j != i {
                    edges.push((i, j, 0.1 + rng.f64()));
                }
            }
        }
        let g = CsrGraph::from_edges(n, &edges).unwrap();
        let c_low = select_seeds(&g, &vec![1.0; n], SeedParams { q: 0.3, eta: 2.0 })
            .iter()
            .filter(|&&s| s)
            .count();
        let c_high = select_seeds(&g, &vec![1.0; n], SeedParams { q: 0.7, eta: 2.0 })
            .iter()
            .filter(|&&s| s)
            .count();
        assert!(c_high > c_low, "Q=0.7 gave {c_high} <= Q=0.3's {c_low}");
    }
}
