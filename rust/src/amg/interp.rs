//! The AMG interpolation operator P (Eq. 4) with bounded interpolation
//! order ("caliber") R.
//!
//! ```text
//!          ⎧ w_ij / Σ_{k∈N_i} w_ik   i ∈ F, j ∈ N_i
//! P_ij  =  ⎨ 1                        i ∈ C, j = I(i)
//!          ⎩ 0                        otherwise
//! ```
//!
//! `N_i = {j ∈ C | ij ∈ E}` are the seed neighbors of a free node. The
//! caliber keeps only the R strongest seed connections per row before
//! normalization — the paper's Table-3 knob controlling coarse-graph
//! density (and, as the paper shows, classifier quality on some sets).
//!
//! A free node with *no* seed neighbor cannot interpolate; such nodes are
//! promoted to seeds here (rare: Algorithm 1 guarantees strong coupling
//! for F-nodes, but approximate k-NN graphs can have satellites).

use crate::graph::csr::{CsrGraph, SparseRowMatrix};

/// Interpolation parameters.
#[derive(Clone, Copy, Debug)]
pub struct InterpParams {
    /// Interpolation order / caliber R: max nonzeros per fine row.
    pub caliber: usize,
}

impl Default for InterpParams {
    fn default() -> Self {
        InterpParams { caliber: 2 }
    }
}

/// Result of building P.
#[derive(Debug)]
pub struct Interpolation {
    /// The operator (n_fine × n_coarse), rows sum to 1.
    pub p: SparseRowMatrix,
    /// For each fine node, `coarse_of[i]` = Some(c) iff i is the seed of
    /// coarse node c.
    pub coarse_of_seed: Vec<Option<u32>>,
    /// Fine seed index of each coarse node (the I(i) numbering).
    pub seed_of_coarse: Vec<u32>,
}

/// Build P given the fine graph and the seed marking (possibly promoting
/// stranded free nodes to seeds — the returned structures reflect that).
pub fn interpolation(
    graph: &CsrGraph,
    is_seed: &[bool],
    params: InterpParams,
) -> Interpolation {
    let n = graph.n();
    let mut is_seed = is_seed.to_vec();

    // Promote stranded F-nodes (no seed neighbor) to seeds.
    loop {
        let mut promoted = false;
        for i in 0..n {
            if is_seed[i] {
                continue;
            }
            let (idx, _) = graph.row(i);
            if !idx.iter().any(|&j| is_seed[j as usize]) {
                is_seed[i] = true;
                promoted = true;
            }
        }
        if !promoted {
            break;
        }
    }

    // Number the coarse nodes by fine seed order (I(i)).
    let mut coarse_of_seed: Vec<Option<u32>> = vec![None; n];
    let mut seed_of_coarse = Vec::new();
    for i in 0..n {
        if is_seed[i] {
            coarse_of_seed[i] = Some(seed_of_coarse.len() as u32);
            seed_of_coarse.push(i as u32);
        }
    }

    let caliber = params.caliber.max(1);
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::with_capacity(n);
    for i in 0..n {
        if let Some(c) = coarse_of_seed[i] {
            rows.push(vec![(c, 1.0)]);
            continue;
        }
        let (idx, w) = graph.row(i);
        // Collect seed neighbors with weights.
        let mut cand: Vec<(u32, f64)> = idx
            .iter()
            .zip(w)
            .filter_map(|(&j, &wij)| coarse_of_seed[j as usize].map(|c| (c, wij)))
            .collect();
        debug_assert!(!cand.is_empty(), "stranded node {i} after promotion");
        // Keep the R strongest.
        cand.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        cand.truncate(caliber);
        // A node can reach the same coarse aggregate via one seed only
        // (seeds are distinct coarse columns), so no dedup needed.
        let total: f64 = cand.iter().map(|&(_, w)| w).sum();
        rows.push(
            cand.into_iter()
                .map(|(c, wij)| (c, (wij / total) as f32))
                .collect(),
        );
    }
    Interpolation {
        p: SparseRowMatrix::from_rows(rows, seed_of_coarse.len()),
        coarse_of_seed,
        seed_of_coarse,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Path 0-1-2-3-4 with seeds {0, 4}.
    fn path_with_end_seeds() -> (CsrGraph, Vec<bool>) {
        let g = CsrGraph::from_edges(
            5,
            &[(0, 1, 2.0), (1, 2, 1.0), (2, 3, 1.0), (3, 4, 2.0)],
        )
        .unwrap();
        let mut seeds = vec![false; 5];
        seeds[0] = true;
        seeds[4] = true;
        (g, seeds)
    }

    #[test]
    fn seed_rows_are_identity() {
        let (g, seeds) = path_with_end_seeds();
        let interp = interpolation(&g, &seeds, InterpParams { caliber: 2 });
        // node 2 has no seed neighbor → promoted; coarse count = 3
        assert_eq!(interp.seed_of_coarse.len(), 3);
        let c0 = interp.coarse_of_seed[0].unwrap();
        assert_eq!(interp.p.row(0), &[(c0, 1.0)]);
    }

    #[test]
    fn f_rows_are_weight_normalized() {
        let (g, seeds) = path_with_end_seeds();
        let interp = interpolation(&g, &seeds, InterpParams { caliber: 2 });
        // node 1 neighbors: 0 (seed, w=2), 2 (promoted seed, w=1)
        let row = interp.p.row(1);
        assert_eq!(row.len(), 2);
        let sum: f32 = row.iter().map(|&(_, v)| v).sum();
        assert!((sum - 1.0).abs() < 1e-6);
        let c0 = interp.coarse_of_seed[0].unwrap();
        let w0 = row.iter().find(|&&(c, _)| c == c0).unwrap().1;
        assert!((w0 - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn caliber_one_gives_hard_aggregation() {
        let (g, seeds) = path_with_end_seeds();
        let interp = interpolation(&g, &seeds, InterpParams { caliber: 1 });
        for i in 0..5 {
            let row = interp.p.row(i);
            assert_eq!(row.len(), 1, "row {i} must have single entry");
            assert!((row[0].1 - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn rows_sum_to_one_always() {
        let (g, seeds) = path_with_end_seeds();
        for r in [1usize, 2, 4] {
            let interp = interpolation(&g, &seeds, InterpParams { caliber: r });
            for s in interp.p.row_sums() {
                assert!((s - 1.0).abs() < 1e-6, "caliber {r}: row sum {s}");
            }
        }
    }

    #[test]
    fn caliber_bounds_row_nnz() {
        // Dense-ish graph, few seeds, caliber 2.
        let mut edges = Vec::new();
        for i in 0..10u32 {
            for j in (i + 1)..10 {
                edges.push((i, j, 1.0 + (i + j) as f64));
            }
        }
        let g = CsrGraph::from_edges(10, &edges).unwrap();
        let mut seeds = vec![false; 10];
        for s in [0, 3, 7] {
            seeds[s] = true;
        }
        let interp = interpolation(&g, &seeds, InterpParams { caliber: 2 });
        for i in 0..10 {
            assert!(interp.p.row(i).len() <= 2);
        }
        // caliber 2 keeps the two strongest: for node 9, neighbors seeds
        // 0 (w=10), 3 (w=13), 7 (w=17) -> keep {3,7} renormalized.
        let row9 = interp.p.row(9);
        let c3 = interp.coarse_of_seed[3].unwrap();
        let c7 = interp.coarse_of_seed[7].unwrap();
        let w3 = row9.iter().find(|&&(c, _)| c == c3).unwrap().1;
        let w7 = row9.iter().find(|&&(c, _)| c == c7).unwrap().1;
        assert!((w3 - 13.0 / 30.0).abs() < 1e-6);
        assert!((w7 - 17.0 / 30.0).abs() < 1e-6);
    }

    #[test]
    fn isolated_free_node_is_promoted() {
        let g = CsrGraph::from_edges(3, &[(0, 1, 1.0)]).unwrap();
        let seeds = vec![true, false, false];
        let interp = interpolation(&g, &seeds, InterpParams::default());
        // node 2 is isolated: promoted to seed
        assert_eq!(interp.seed_of_coarse.len(), 2);
        assert!(interp.coarse_of_seed[2].is_some());
    }
}
