//! The multilevel hierarchy of one class's data manifold.
//!
//! `{G_i = (V_i, E_i)}_{i=0..K}` with G_0 the affinity graph of the
//! original class training set. Coarsening runs until the level size drops
//! below the coarsest threshold (paper: ~500 points), the level budget is
//! exhausted, or coarsening stagnates (tiny reduction factor — a safety
//! valve the paper does not need on its well-behaved inputs).
//!
//! Coarsening is applied **separately per class** (C⁺ points are never
//! aggregated with C⁻ points); the imbalanced-class "copy-through" of the
//! paper's note is realized in [`crate::mlsvm::trainer`] by aligning two
//! hierarchies of different depth from the coarsest level upward.

use crate::amg::coarsen::{coarsen_level, CoarseLevel, CoarsenParams};
use crate::amg::interp::InterpParams;
use crate::amg::seeds::SeedParams;
use crate::data::matrix::Matrix;
use crate::error::Result;
use crate::graph::affinity::affinity_graph;
use crate::graph::csr::{CsrGraph, SparseRowMatrix};
use crate::knn::KnnBackend;

/// Hierarchy construction parameters (paper defaults).
#[derive(Clone, Copy, Debug)]
pub struct HierarchyParams {
    /// k of the k-NN affinity graph (paper: 10).
    pub knn_k: usize,
    /// k-NN backend (exact below ~1.5k points, rp-forest above by default).
    pub knn_backend: KnnBackend,
    /// Algorithm-1 coupling threshold Q (paper: 0.5).
    pub q: f64,
    /// Algorithm-1 future-volume outlier factor η (paper: 2).
    pub eta: f64,
    /// Interpolation order / caliber R (paper Table 3; default 2).
    pub caliber: usize,
    /// Stop when a level has at most this many points (paper: ~500).
    pub coarsest_size: usize,
    /// Hard cap on levels.
    pub max_levels: usize,
    /// Stop if a step shrinks the level by less than this factor.
    pub min_reduction: f64,
    /// RNG seed for the approximate k-NN backend.
    pub seed: u64,
}

impl Default for HierarchyParams {
    fn default() -> Self {
        HierarchyParams {
            knn_k: 10,
            knn_backend: KnnBackend::Auto,
            q: 0.5,
            eta: 2.0,
            caliber: 2,
            coarsest_size: 500,
            max_levels: 30,
            min_reduction: 0.95,
            seed: 0,
        }
    }
}

/// One level of the hierarchy. Level 0 is the finest (original points).
#[derive(Debug)]
pub struct Level {
    /// Points at this level (aggregate centroids for l > 0).
    pub points: Matrix,
    /// Volumes (all 1.0 at level 0).
    pub volumes: Vec<f64>,
    /// Affinity graph at this level.
    pub graph: CsrGraph,
    /// Interpolation from the next-finer level (None at level 0).
    pub p: Option<SparseRowMatrix>,
    /// Aggregate membership I⁻¹ over next-finer indices (None at level 0).
    pub aggregates: Option<Vec<Vec<u32>>>,
    /// Fine seed index of each node here (None at level 0).
    pub seed_of_coarse: Option<Vec<u32>>,
}

impl Level {
    /// Number of points at this level.
    pub fn len(&self) -> usize {
        self.points.rows()
    }

    /// True when the level is empty.
    pub fn is_empty(&self) -> bool {
        self.points.rows() == 0
    }
}

/// A per-class AMG hierarchy, finest level first.
#[derive(Debug)]
pub struct Hierarchy {
    /// Levels, `levels[0]` = finest.
    pub levels: Vec<Level>,
}

impl Hierarchy {
    /// Build the hierarchy for one class's points.
    pub fn build(points: Matrix, params: HierarchyParams) -> Result<Hierarchy> {
        let n0 = points.rows();
        let graph = affinity_graph(&points, params.knn_k, params.knn_backend, params.seed)?;
        let volumes = vec![1.0; n0];
        let mut levels = vec![Level {
            points,
            volumes,
            graph,
            p: None,
            aggregates: None,
            seed_of_coarse: None,
        }];
        let cparams = CoarsenParams {
            seed: SeedParams {
                q: params.q,
                eta: params.eta,
            },
            interp: InterpParams {
                caliber: params.caliber,
            },
        };
        while levels.len() < params.max_levels {
            let fine = levels.last().unwrap();
            let nf = fine.len();
            if nf <= params.coarsest_size {
                break;
            }
            let CoarseLevel {
                points,
                volumes,
                graph,
                p,
                seed_of_coarse,
                aggregates,
            } = coarsen_level(&fine.points, &fine.volumes, &fine.graph, cparams)?;
            let nc = points.rows();
            if nc as f64 > params.min_reduction * nf as f64 {
                // stagnation: keep the previous level as coarsest
                break;
            }
            levels.push(Level {
                points,
                volumes,
                graph,
                p: Some(p),
                aggregates: Some(aggregates),
                seed_of_coarse: Some(seed_of_coarse),
            });
        }
        Ok(Hierarchy { levels })
    }

    /// Build two independent hierarchies concurrently (the C⁺ and C⁻
    /// coarsening phases of the multilevel trainer: separate point sets,
    /// seeds and kNN graphs — nothing is shared). One build runs on a
    /// spawned thread, the other on the caller's thread; with a single
    /// worker configured the builds run back-to-back instead. Each build
    /// is fully deterministic given its params, so the result is identical
    /// either way.
    ///
    /// Error precedence matches the sequential order: `a`'s error is
    /// reported first when both fail.
    ///
    /// Both builds keep their internal pool parallelism (neither runs on
    /// a pool worker), so the coarsening phase may briefly run up to
    /// 2 × `num_threads()` busy threads. That bounded oversubscription is
    /// deliberate: the two builds rarely finish together (class sizes
    /// differ), and serializing each build's interior would idle most
    /// cores for the tail of the longer one.
    pub fn build_pair(
        a: (Matrix, HierarchyParams),
        b: (Matrix, HierarchyParams),
    ) -> Result<(Hierarchy, Hierarchy)> {
        // Inside a pool section (e.g. a parallel one-vs-rest class job)
        // stay fully sequential: the caller-side build is already
        // suppressed by the nested-parallelism guard, but a scoped thread
        // would start with a clean thread-local and fan out a full worker
        // set — threads² across classes.
        if crate::util::pool::num_threads() <= 1 || crate::util::pool::in_worker() {
            return Ok((Hierarchy::build(a.0, a.1)?, Hierarchy::build(b.0, b.1)?));
        }
        std::thread::scope(|s| {
            let ha = s.spawn(move || Hierarchy::build(a.0, a.1));
            let hb = Hierarchy::build(b.0, b.1);
            let ha = ha.join().expect("hierarchy build thread panicked");
            Ok((ha?, hb?))
        })
    }

    /// Number of levels (≥ 1).
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// The coarsest level.
    pub fn coarsest(&self) -> &Level {
        self.levels.last().unwrap()
    }

    /// Expand a set of node indices at `level` to the next-finer level
    /// via aggregate membership (the I⁻¹ step of Algorithm 3). `level`
    /// must be ≥ 1. The result is deduplicated and sorted.
    pub fn expand_to_finer(&self, level: usize, nodes: &[u32]) -> Vec<u32> {
        assert!(level >= 1 && level < self.depth());
        let aggs = self.levels[level]
            .aggregates
            .as_ref()
            .expect("level >= 1 has aggregates");
        let mut out: Vec<u32> = nodes
            .iter()
            .flat_map(|&q| aggs[q as usize].iter().copied())
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Total volume at each level (conserved across levels; used by tests
    /// and the micro bench).
    pub fn level_volumes(&self) -> Vec<f64> {
        self.levels
            .iter()
            .map(|l| l.volumes.iter().sum())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Pcg64, Rng};

    fn clustered(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seed_from(seed);
        let mut m = Matrix::zeros(n, d);
        for i in 0..n {
            let c = (i % 8) as f64 * 5.0;
            for j in 0..d {
                m.set(i, j, (c + rng.normal()) as f32);
            }
        }
        m
    }

    fn small_params() -> HierarchyParams {
        HierarchyParams {
            coarsest_size: 60,
            ..Default::default()
        }
    }

    #[test]
    fn builds_decreasing_levels_down_to_threshold() {
        let pts = clustered(1000, 6, 31);
        let h = Hierarchy::build(pts, small_params()).unwrap();
        assert!(h.depth() >= 2, "expected multiple levels");
        for w in h.levels.windows(2) {
            assert!(w[1].len() < w[0].len());
        }
        assert!(h.coarsest().len() <= 160, "coarsest too big: {}", h.coarsest().len());
    }

    #[test]
    fn volume_is_conserved_across_all_levels() {
        let pts = clustered(800, 5, 32);
        let h = Hierarchy::build(pts, small_params()).unwrap();
        let vols = h.level_volumes();
        for v in &vols {
            assert!((v - 800.0).abs() < 1e-6 * 800.0, "volume drift: {vols:?}");
        }
    }

    #[test]
    fn small_input_yields_single_level() {
        let pts = clustered(50, 4, 33);
        let h = Hierarchy::build(pts, small_params()).unwrap();
        assert_eq!(h.depth(), 1);
        assert_eq!(h.coarsest().len(), 50);
    }

    #[test]
    fn expand_to_finer_returns_union_of_aggregates() {
        let pts = clustered(600, 5, 34);
        let h = Hierarchy::build(pts, small_params()).unwrap();
        if h.depth() < 2 {
            return;
        }
        let l = h.depth() - 1;
        let all: Vec<u32> = (0..h.levels[l].len() as u32).collect();
        let fine = h.expand_to_finer(l, &all);
        // expanding every coarse node covers every finer node
        assert_eq!(fine.len(), h.levels[l - 1].len());
        // expanding a single node gives a small non-empty set
        let one = h.expand_to_finer(l, &[0]);
        assert!(!one.is_empty());
        assert!(one.len() < fine.len());
    }

    #[test]
    fn pair_build_matches_sequential_builds() {
        let pa = clustered(500, 5, 36);
        let pb = clustered(420, 5, 37);
        let mut params_b = small_params();
        params_b.seed = 99;
        let (ha, hb) =
            Hierarchy::build_pair((pa.clone(), small_params()), (pb.clone(), params_b)).unwrap();
        let sa = Hierarchy::build(pa, small_params()).unwrap();
        let sb = Hierarchy::build(pb, params_b).unwrap();
        assert_eq!(ha.depth(), sa.depth());
        assert_eq!(hb.depth(), sb.depth());
        for (l, m) in ha.levels.iter().zip(&sa.levels) {
            assert_eq!(l.len(), m.len());
            assert_eq!(l.volumes, m.volumes);
        }
        for (l, m) in hb.levels.iter().zip(&sb.levels) {
            assert_eq!(l.len(), m.len());
            assert_eq!(l.volumes, m.volumes);
        }
    }

    #[test]
    fn caliber_increases_aggregate_overlap() {
        let pts = clustered(700, 5, 35);
        let mut p1 = small_params();
        p1.caliber = 1;
        let mut p4 = small_params();
        p4.caliber = 4;
        let h1 = Hierarchy::build(pts.clone(), p1).unwrap();
        let h4 = Hierarchy::build(pts, p4).unwrap();
        if h1.depth() < 2 || h4.depth() < 2 {
            return;
        }
        let nnz1: usize = h1.levels[1].p.as_ref().unwrap().entries.len();
        let nnz4: usize = h4.levels[1].p.as_ref().unwrap().entries.len();
        assert!(
            nnz4 > nnz1,
            "caliber 4 should densify P: {nnz4} vs {nnz1}"
        );
    }
}
