//! Algebraic multigrid coarsening of affinity graphs — the heart of the
//! paper (§3, Algorithm 1, Eq. 3–4).
//!
//! A hierarchy of coarse representations of one class's data manifold is
//! built by repeatedly: (1) selecting a dominating set of *seed* nodes by
//! future-volume ordering ([`seeds`], Algorithm 1); (2) forming the AMG
//! interpolation operator P with bounded interpolation order / caliber R
//! ([`interp`], Eq. 4); (3) aggregating data points, volumes and edges
//! through P ([`coarsen`]) — coarse points are volume-weighted centroids
//! of (fractional) aggregates, coarse edges come from the Galerkin triple
//! product PᵀWP. [`hierarchy`] drives levels until the coarsest-size
//! threshold.

pub mod coarsen;
pub mod future_volume;
pub mod hierarchy;
pub mod interp;
pub mod seeds;

pub use coarsen::{coarsen_level, CoarseLevel};
pub use future_volume::future_volumes;
pub use hierarchy::{Hierarchy, HierarchyParams, Level};
pub use interp::{interpolation, InterpParams};
pub use seeds::{select_seeds, SeedParams};
