//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path —
//! Python is never invoked at run time.
//!
//! * [`client`] — the PJRT CPU client, artifact manifest parsing, and a
//!   compile cache (one executable per artifact, compiled on first use);
//! * [`rbf`] — the padded RBF kernel-tile executor (SMO row backend) and
//!   the batched decision-function executor (prediction router), both
//!   validated against the pure-rust kernels in tests.

pub mod client;
pub mod rbf;

pub use client::{Artifacts, Runtime};
pub use rbf::{PjrtDecision, PjrtRowBackend};
