//! PJRT CPU client + artifact registry.
//!
//! Artifacts are HLO **text** (see `python/compile/aot.py` for why text,
//! not serialized protos). `manifest.txt` lists one artifact per line:
//!
//! ```text
//! <name> <file> k=v k=v ...
//! ```
//!
//! Executables are compiled on first use and cached for the process
//! lifetime (AOT at the artifact level, JIT-once at the PJRT level — the
//! same model as serving systems that warm a compile cache at startup).

use crate::error::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Default artifact directory: `$MLSVM_ARTIFACTS` or `./artifacts`.
fn default_artifact_dir() -> PathBuf {
    std::env::var("MLSVM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Artifacts {
    dir: PathBuf,
    entries: HashMap<String, (PathBuf, HashMap<String, usize>)>,
}

impl Artifacts {
    /// Parse `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Artifacts> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest.display()
            ))
        })?;
        let mut entries = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut toks = line.split_whitespace();
            let name = toks
                .next()
                .ok_or_else(|| Error::Runtime("manifest: empty line".into()))?
                .to_string();
            let file = toks
                .next()
                .ok_or_else(|| Error::Runtime(format!("manifest: {name} missing file")))?;
            let mut meta = HashMap::new();
            for kv in toks {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| Error::Runtime(format!("manifest: bad meta '{kv}'")))?;
                let v: usize = v
                    .parse()
                    .map_err(|_| Error::Runtime(format!("manifest: bad meta value '{kv}'")))?;
                meta.insert(k.to_string(), v);
            }
            entries.insert(name, (dir.join(file), meta));
        }
        Ok(Artifacts { dir, entries })
    }

    /// Artifact directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Names available.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(|s| s.as_str()).collect()
    }

    /// Metadata value `key` of artifact `name`.
    pub fn meta(&self, name: &str, key: &str) -> Result<usize> {
        self.entries
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("unknown artifact '{name}'")))?
            .1
            .get(key)
            .copied()
            .ok_or_else(|| Error::Runtime(format!("artifact '{name}' has no meta '{key}'")))
    }

    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    fn path(&self, name: &str) -> Result<&Path> {
        Ok(&self
            .entries
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("unknown artifact '{name}'")))?
            .0)
    }
}

/// A PJRT CPU runtime with a compile cache.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    /// Manifest.
    pub artifacts: Artifacts,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create the CPU client and parse the manifest in `dir`.
    pub fn new(dir: impl AsRef<Path>) -> Result<Runtime> {
        let artifacts = Artifacts::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PJRT cpu client: {e}")))?;
        Ok(Runtime {
            client,
            artifacts,
            executables: HashMap::new(),
        })
    }

    /// Default artifact directory: `$MLSVM_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        default_artifact_dir()
    }

    /// PJRT platform string (e.g. "cpu") — diagnostics.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.artifacts.path(name)?.to_path_buf();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
        )
        .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {name}: {e}")))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute artifact `name` on f32 inputs given as (data, dims) pairs;
    /// returns the flattened f32 output of the single tuple element.
    pub fn execute_f32(&mut self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        self.ensure_compiled(name)?;
        let exe = self.executables.get(name).expect("just compiled");
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = if dims.is_empty() {
                xla::Literal::scalar(data[0])
            } else {
                xla::Literal::vec1(data)
                    .reshape(dims)
                    .map_err(|e| Error::Runtime(format!("reshape {dims:?}: {e}")))?
            };
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("execute {name}: {e}")))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch {name}: {e}")))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = lit
            .to_tuple1()
            .map_err(|e| Error::Runtime(format!("untuple {name}: {e}")))?;
        out.to_vec::<f32>()
            .map_err(|e| Error::Runtime(format!("to_vec {name}: {e}")))
    }
}

/// Stub runtime for builds without the `pjrt` feature: same surface as the
/// real [`Runtime`], but construction always fails with a clear message so
/// every artifact-gated call site (tests, CLI, router) degrades to the
/// pure-rust path. This keeps the default build free of the unvendored
/// `xla` crate.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    /// Manifest (never populated — the stub constructor always errors).
    pub artifacts: Artifacts,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Parse the manifest, then report the missing feature. Manifest
    /// errors (missing/corrupt) take precedence so diagnostics stay
    /// faithful to the artifact state.
    pub fn new(dir: impl AsRef<Path>) -> Result<Runtime> {
        let _ = Artifacts::load(dir)?;
        Err(Error::Runtime(
            "built without the `pjrt` feature: vendor the `xla` crate and rebuild with \
             `--features pjrt` to execute AOT artifacts"
                .into(),
        ))
    }

    /// Default artifact directory: `$MLSVM_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        default_artifact_dir()
    }

    /// PJRT platform string — diagnostics.
    pub fn platform(&self) -> String {
        "unavailable (pjrt feature disabled)".to_string()
    }

    /// Always fails: artifact execution needs the `pjrt` feature.
    pub fn execute_f32(&mut self, name: &str, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        Err(Error::Runtime(format!(
            "execute {name}: built without the `pjrt` feature"
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = Runtime::default_dir();
        if dir.join("manifest.txt").exists() {
            Some(dir)
        } else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            None
        }
    }

    #[test]
    fn manifest_parses_and_lists_artifacts() {
        let Some(dir) = artifacts_dir() else { return };
        let arts = Artifacts::load(&dir).unwrap();
        let mut names = arts.names();
        names.sort_unstable();
        assert_eq!(names, vec!["decision", "rbf_tile"]);
        assert_eq!(arts.meta("rbf_tile", "d").unwrap(), 128);
        assert!(arts.meta("rbf_tile", "nope").is_err());
        assert!(arts.meta("nope", "d").is_err());
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let err = Artifacts::load("/definitely/not/here").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn rbf_tile_executes_and_matches_rust_kernel() {
        let Some(dir) = artifacts_dir() else { return };
        let mut rt = Runtime::new(&dir).unwrap();
        let m = rt.artifacts.meta("rbf_tile", "m").unwrap();
        let n = rt.artifacts.meta("rbf_tile", "n").unwrap();
        let d = rt.artifacts.meta("rbf_tile", "d").unwrap();
        // x rows: simple patterns in the first 3 features, rest zero.
        let mut x = vec![0.0f32; m * d];
        let mut y = vec![0.0f32; n * d];
        for i in 0..m {
            x[i * d] = (i % 7) as f32 * 0.25;
            x[i * d + 1] = (i % 3) as f32;
        }
        for j in 0..n {
            y[j * d] = (j % 5) as f32 * 0.5;
            y[j * d + 2] = 1.0;
        }
        let gamma = 0.3f32;
        let out = rt
            .execute_f32(
                "rbf_tile",
                &[
                    (&x, &[m as i64, d as i64]),
                    (&y, &[n as i64, d as i64]),
                    (&[gamma], &[]),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), m * n);
        let kern = crate::svm::kernel::RbfKernel { gamma: gamma as f64 };
        use crate::svm::kernel::Kernel;
        for &(i, j) in &[(0usize, 0usize), (1, 3), (17, 101), (255, 254)] {
            let want = kern.eval(&x[i * d..(i + 1) * d], &y[j * d..(j + 1) * d]) as f32;
            let got = out[i * n + j];
            assert!(
                (got - want).abs() < 1e-5,
                "K[{i}][{j}] = {got}, want {want}"
            );
        }
    }
}
