//! The two hot-path executors over the AOT artifacts.
//!
//! * [`PjrtRowBackend`] — a [`RowBackend`] for SMO that precomputes the
//!   full Gram matrix of a (coarse-level) training set by tiling it
//!   through the `rbf_tile` artifact. Coarse-level sets are ≤ Q_dt
//!   (~10³) points, so the dense Gram fits easily and every SMO kernel
//!   row becomes a memcpy — this is how a real TPU deployment would batch
//!   the MXU work.
//! * [`PjrtDecision`] — batched SVM decision values through the
//!   `decision` artifact, chunking queries (DEC_Q) and support vectors
//!   (DEC_S; the kernel sum is linear in the SV set so chunks add up).
//!
//! Padding contract (validated in python/tests and here): extra feature
//! columns are zero (exact for RBF); padded SV rows carry zero
//! coefficients; padded query/X rows produce garbage that is sliced off.

use crate::data::matrix::Matrix;
use crate::error::{Error, Result};
use crate::runtime::client::Runtime;
use crate::svm::kernel::RowBackend;
use crate::svm::model::SvmModel;

fn pad_rows(points: &Matrix, rows: usize, d: usize) -> Result<Vec<f32>> {
    if points.cols() > d {
        return Err(Error::Runtime(format!(
            "data has {} features, artifact supports at most {d}",
            points.cols()
        )));
    }
    if points.rows() > rows {
        return Err(Error::Runtime(format!(
            "block of {} rows exceeds artifact tile {rows}",
            points.rows()
        )));
    }
    let mut buf = vec![0.0f32; rows * d];
    for i in 0..points.rows() {
        buf[i * d..i * d + points.cols()].copy_from_slice(points.row(i));
    }
    Ok(buf)
}

/// Gram-precomputing SMO row backend over the `rbf_tile` artifact.
pub struct PjrtRowBackend {
    n: usize,
    gram: Vec<f32>, // n x n row-major
}

impl PjrtRowBackend {
    /// Precompute the full Gram matrix of `points` with bandwidth `gamma`
    /// by executing the rbf_tile artifact over all (row, col) tile pairs.
    pub fn new(rt: &mut Runtime, points: &Matrix, gamma: f64) -> Result<PjrtRowBackend> {
        let tm = rt.artifacts.meta("rbf_tile", "m")?;
        let tn = rt.artifacts.meta("rbf_tile", "n")?;
        let d = rt.artifacts.meta("rbf_tile", "d")?;
        let n = points.rows();
        let mut gram = vec![0.0f32; n * n];
        let gamma32 = [gamma as f32];
        let row_tiles = n.div_ceil(tm);
        let col_tiles = n.div_ceil(tn);
        for bi in 0..row_tiles {
            let r0 = bi * tm;
            let r1 = (r0 + tm).min(n);
            let xs: Vec<usize> = (r0..r1).collect();
            let x = pad_rows(&points.select_rows(&xs), tm, d)?;
            for bj in 0..col_tiles {
                let c0 = bj * tn;
                let c1 = (c0 + tn).min(n);
                let ys: Vec<usize> = (c0..c1).collect();
                let y = pad_rows(&points.select_rows(&ys), tn, d)?;
                let out = rt.execute_f32(
                    "rbf_tile",
                    &[
                        (&x, &[tm as i64, d as i64]),
                        (&y, &[tn as i64, d as i64]),
                        (&gamma32, &[]),
                    ],
                )?;
                for (ri, row) in (r0..r1).enumerate() {
                    let src = &out[ri * tn..ri * tn + (c1 - c0)];
                    gram[row * n + c0..row * n + c1].copy_from_slice(src);
                }
            }
        }
        Ok(PjrtRowBackend { n, gram })
    }
}

impl RowBackend for PjrtRowBackend {
    fn len(&self) -> usize {
        self.n
    }

    fn fill_row(&self, i: usize, out: &mut [f32]) {
        out.copy_from_slice(&self.gram[i * self.n..(i + 1) * self.n]);
    }
}

/// Batched decision-function executor over the `decision` artifact.
pub struct PjrtDecision {
    s: usize,
    q: usize,
    d: usize,
    /// SV chunks, each padded to (s, d), with padded coef chunks.
    sv_chunks: Vec<(Vec<f32>, Vec<f32>)>,
    gamma: f32,
    rho: f32,
}

impl PjrtDecision {
    /// Prepare a model for batched execution (pads/chunks the SV set once).
    pub fn new(rt: &Runtime, model: &SvmModel) -> Result<PjrtDecision> {
        let s = rt.artifacts.meta("decision", "s")?;
        let q = rt.artifacts.meta("decision", "q")?;
        let d = rt.artifacts.meta("decision", "d")?;
        let gamma = match model.kernel {
            crate::svm::kernel::KernelKind::Rbf { gamma } => gamma as f32,
            other => {
                return Err(Error::Runtime(format!(
                    "decision artifact is RBF-only, model has {other:?}"
                )))
            }
        };
        if model.sv.cols() > d {
            return Err(Error::Runtime(format!(
                "model dim {} exceeds artifact dim {d}",
                model.sv.cols()
            )));
        }
        let mut sv_chunks = Vec::new();
        let nsv = model.n_sv();
        let mut start = 0usize;
        while start < nsv {
            let end = (start + s).min(nsv);
            let idx: Vec<usize> = (start..end).collect();
            let sv = pad_rows(&model.sv.select_rows(&idx), s, d)?;
            let mut coef = vec![0.0f32; s];
            for (k, &i) in idx.iter().enumerate() {
                coef[k] = model.sv_coef[i] as f32;
            }
            sv_chunks.push((sv, coef));
            start = end;
        }
        if sv_chunks.is_empty() {
            return Err(Error::Runtime("model has no support vectors".into()));
        }
        Ok(PjrtDecision {
            s,
            q,
            d,
            sv_chunks,
            gamma,
            rho: model.rho as f32,
        })
    }

    /// Maximum query batch per artifact call.
    pub fn batch_size(&self) -> usize {
        self.q
    }

    /// Decision values for all rows of `queries` (any count — chunked).
    pub fn decision_batch(&self, rt: &mut Runtime, queries: &Matrix) -> Result<Vec<f64>> {
        let nq = queries.rows();
        let mut out = Vec::with_capacity(nq);
        let mut start = 0usize;
        while start < nq {
            let end = (start + self.q).min(nq);
            let idx: Vec<usize> = (start..end).collect();
            let qbuf = pad_rows(&queries.select_rows(&idx), self.q, self.d)?;
            // Sum kernel contributions over SV chunks; rho applied once.
            let mut acc = vec![0.0f64; end - start];
            for (ci, (sv, coef)) in self.sv_chunks.iter().enumerate() {
                // the artifact subtracts rho each call: pass rho only on
                // the first chunk, zero after.
                let rho = if ci == 0 { self.rho } else { 0.0 };
                let vals = rt.execute_f32(
                    "decision",
                    &[
                        (sv, &[self.s as i64, self.d as i64]),
                        (coef, &[self.s as i64]),
                        (&qbuf, &[self.q as i64, self.d as i64]),
                        (&[self.gamma], &[]),
                        (&[rho], &[]),
                    ],
                )?;
                for (k, a) in acc.iter_mut().enumerate() {
                    *a += vals[k] as f64;
                }
            }
            out.extend(acc);
            start = end;
        }
        Ok(out)
    }

    /// Predicted labels through the artifact path.
    pub fn predict_batch(&self, rt: &mut Runtime, queries: &Matrix) -> Result<Vec<i8>> {
        Ok(self
            .decision_batch(rt, queries)?
            .into_iter()
            .map(|d| if d > 0.0 { 1 } else { -1 })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::two_gaussians;
    use crate::svm::kernel::{KernelKind, RustRowBackend};
    use crate::svm::smo::{train, SvmParams};
    use crate::util::rng::Pcg64;

    fn runtime() -> Option<Runtime> {
        let dir = Runtime::default_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return None;
        }
        match Runtime::new(dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping: {e}");
                None
            }
        }
    }

    #[test]
    fn pjrt_gram_matches_rust_backend() {
        let Some(mut rt) = runtime() else { return };
        let mut rng = Pcg64::seed_from(91);
        let ds = two_gaussians(300, 100, 10, 3.0, &mut rng);
        let gamma = 0.15;
        let pjrt = PjrtRowBackend::new(&mut rt, &ds.points, gamma).unwrap();
        let rust = RustRowBackend::new(&ds.points, KernelKind::Rbf { gamma });
        let n = ds.len();
        let mut a = vec![0.0f32; n];
        let mut b = vec![0.0f32; n];
        for i in (0..n).step_by(37) {
            pjrt.fill_row(i, &mut a);
            rust.fill_row(i, &mut b);
            for j in 0..n {
                assert!(
                    (a[j] - b[j]).abs() < 1e-5,
                    "K[{i}][{j}]: pjrt {} vs rust {}",
                    a[j],
                    b[j]
                );
            }
        }
    }

    #[test]
    fn smo_on_pjrt_backend_matches_rust_solution() {
        let Some(mut rt) = runtime() else { return };
        let mut rng = Pcg64::seed_from(92);
        let ds = two_gaussians(150, 80, 6, 3.0, &mut rng);
        let params = SvmParams {
            kernel: KernelKind::Rbf { gamma: 0.2 },
            ..Default::default()
        };
        let pjrt = PjrtRowBackend::new(&mut rt, &ds.points, 0.2).unwrap();
        let res_p = crate::svm::smo::solve(&pjrt, &ds.labels, &params, None).unwrap();
        let rust = RustRowBackend::new(&ds.points, params.kernel);
        let res_r = crate::svm::smo::solve(&rust, &ds.labels, &params, None).unwrap();
        // identical deterministic pivoting on near-identical kernels →
        // objective-level agreement (allow small drift from f32 kernels)
        assert!((res_p.rho - res_r.rho).abs() < 1e-3, "{} vs {}", res_p.rho, res_r.rho);
        let diff: f64 = res_p
            .alpha
            .iter()
            .zip(&res_r.alpha)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
            / res_p.alpha.len() as f64;
        assert!(diff < 1e-3, "mean |Δα| = {diff}");
    }

    #[test]
    fn pjrt_decision_matches_model_decision() {
        let Some(mut rt) = runtime() else { return };
        let mut rng = Pcg64::seed_from(93);
        let ds = two_gaussians(400, 150, 8, 2.5, &mut rng);
        let params = SvmParams {
            kernel: KernelKind::Rbf { gamma: 0.1 },
            c_pos: 2.0,
            c_neg: 1.0,
            ..Default::default()
        };
        let model = train(&ds.points, &ds.labels, &params).unwrap();
        // ensure multi-chunk coverage when nsv > DEC_S is rare here; still
        // exercises the padded path.
        let dec = PjrtDecision::new(&rt, &model).unwrap();
        let got = dec.decision_batch(&mut rt, &ds.points).unwrap();
        let want = model.decision_batch(&ds.points);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-3 * w.abs().max(1.0), "q{i}: {g} vs {w}");
        }
    }

    #[test]
    fn sv_chunking_splits_large_models() {
        let Some(mut rt) = runtime() else { return };
        // Build a synthetic "model" with more SVs than DEC_S by hand.
        let s_cap = rt.artifacts.meta("decision", "s").unwrap();
        let nsv = s_cap + 37;
        let mut rng = Pcg64::seed_from(94);
        let ds = two_gaussians(nsv / 2, nsv - nsv / 2, 4, 1.0, &mut rng);
        use crate::util::rng::Rng;
        let model = SvmModel {
            sv: ds.points.clone(),
            sv_coef: (0..nsv).map(|_| rng.normal() * 0.1).collect(),
            rho: 0.05,
            kernel: KernelKind::Rbf { gamma: 0.3 },
            sv_indices: (0..nsv).collect(),
            sv_labels: ds.labels.clone(),
        };
        let dec = PjrtDecision::new(&rt, &model).unwrap();
        assert_eq!(dec.sv_chunks.len(), 2);
        let probe = ds.points.select_rows(&(0..50).collect::<Vec<_>>());
        let got = dec.decision_batch(&mut rt, &probe).unwrap();
        let want = model.decision_batch(&probe);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 2e-3 * w.abs().max(1.0), "{g} vs {w}");
        }
    }
}
