//! Model selection: uniform-design (UD) parameter search [12].
//!
//! The paper tunes (C⁺, C⁻, γ) with the UD methodology of Huang et al. —
//! a low-discrepancy design over the (log C, log γ) plane evaluated by
//! cross validation, followed by a second, contracted design around the
//! first-stage winner. The multilevel framework's twist (§3, Algorithm 3)
//! is **parameter inheritance**: at finer levels the search is re-centered
//! on the parameters inherited from the coarser level, and skipped
//! entirely once the level's training set exceeds `Q_dt`.
//!
//! The candidate grid is evaluated in parallel over [`crate::util::pool`]
//! with a deterministic reduction and per-fold shared distance caches —
//! see [`search`] for the determinism contract.

pub mod search;
pub mod ud;

pub use search::{ud_search, UdSearchConfig, UdSearchOutcome, WeightScheme};
pub use ud::ud_points;
