//! Two-stage UD parameter search with k-fold cross validation, maximizing
//! G-mean (the paper's κ).
//!
//! Stage 1 scatters `stage1_points` UD points over the (log₂C, log₂γ)
//! search box (or a contracted box around an inherited center — the
//! multilevel parameter-inheritance of Algorithm 3); stage 2 re-centers a
//! contracted design on the stage-1 winner. Each candidate is scored by
//! stratified k-fold WSVM cross validation.
//!
//! WSVM class weights follow the standard cost-sensitive coupling
//! `C⁺ = C · n⁻/n⁺` , `C⁻ = C` (the paper tunes (C⁺, C⁻, γ); coupling C⁺
//! to the imbalance ratio reduces the search to the (C, γ) plane — the
//! `weight_ratio_grid` option restores the third degree of freedom by
//! additionally sweeping a multiplier on the coupled ratio).
//!
//! ## Parallel execution, deterministic result
//!
//! The candidate × ratio grid of each stage is dispatched over
//! [`crate::util::pool`] — every trial training is independent. Results
//! are **bit-identical at any thread count**: the stratified fold split is
//! drawn from the caller's RNG once per search (before any trial runs, so
//! the RNG stream does not depend on scheduling), each trial is a pure
//! function of its `(C, γ, ratio)` triple over those shared folds, and the
//! winner is reduced from the per-trial scores in ascending trial order
//! (best by G-mean with the SV-sparsity tie-break; the lowest trial index
//! wins exact ties).
//!
//! Sharing the folds also unlocks the biggest single saving: all RBF
//! candidates on one fold share the same pairwise squared distances, so a
//! per-fold [`DistanceCache`] is computed once and every trial's kernel
//! rows reduce to the cheap `exp(-γ·d²)` pass.

use crate::data::dataset::Dataset;
use crate::data::split::KFold;
use crate::error::Result;
use crate::metrics::Metrics;
use crate::modelsel::ud::{scale_to, ud_points};
use crate::svm::dist::DistanceCache;
use crate::svm::kernel::KernelKind;
use crate::svm::smo::{train_weighted, train_weighted_cached, SvmParams};
use crate::util::pool;
use crate::util::rng::Pcg64;

/// How C⁺ relates to C⁻ during the search.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WeightScheme {
    /// C⁺ = C · n⁻/n⁺ (cost-sensitive default).
    Balanced,
    /// C⁺ = C⁻ = C (plain SVM).
    Equal,
}

/// Search configuration.
#[derive(Clone, Debug)]
pub struct UdSearchConfig {
    /// Stage-1 design size (paper/Huang: 13 or 9).
    pub stage1_points: usize,
    /// Stage-2 design size.
    pub stage2_points: usize,
    /// Full log₂C search interval (used when no center is inherited).
    pub log2c: (f64, f64),
    /// Full log₂γ search interval.
    pub log2g: (f64, f64),
    /// CV folds.
    pub folds: usize,
    /// Class-weight coupling.
    pub weights: WeightScheme,
    /// Extra multipliers swept on the coupled weight ratio (≙ tuning C⁺
    /// independently). `[1.0]` disables the third dimension.
    pub weight_ratio_grid: Vec<f64>,
    /// Box contraction around an inherited center (fraction of the full
    /// half-range used at stage 1 when a center is given).
    pub inherit_shrink: f64,
    /// SMO tolerance/caching for the trial trainings.
    pub base: SvmParams,
}

impl Default for UdSearchConfig {
    fn default() -> Self {
        UdSearchConfig {
            stage1_points: 9,
            stage2_points: 5,
            log2c: (-4.0, 10.0),
            log2g: (-10.0, 4.0),
            folds: 3,
            weights: WeightScheme::Balanced,
            weight_ratio_grid: vec![1.0],
            inherit_shrink: 0.35,
            base: SvmParams::default(),
        }
    }
}

/// Search result.
#[derive(Clone, Debug)]
pub struct UdSearchOutcome {
    /// Winning parameters (C⁺, C⁻ resolved, kernel γ set).
    pub params: SvmParams,
    /// Cross-validated G-mean of the winner.
    pub gmean: f64,
    /// log₂ coordinates of the winner (for inheritance by finer levels).
    pub center: (f64, f64),
    /// Number of (train, fold) evaluations executed.
    pub evaluations: usize,
    /// CV G-mean of every trial in design order (stage 1 then stage 2,
    /// candidates × ratio grid). Bit-identical at any thread count — the
    /// determinism tests compare these directly.
    pub trial_gmeans: Vec<f64>,
}

/// One fold's immutable evaluation context, shared by every trial of a
/// search: the stratified (train, validation) pair, the fold's instance
/// weights, and the precomputed squared-distance geometry all RBF
/// candidates reuse.
struct FoldEval {
    tr: Dataset,
    va: Dataset,
    w: Option<Vec<f64>>,
    dists: Option<DistanceCache>,
}

/// Draw the stratified fold split once (the only RNG consumer of the
/// search — hoisting it is what makes parallel trials deterministic) and
/// precompute each fold's shared context. Degenerate folds (a class
/// missing from the training side, empty validation) are dropped here,
/// exactly as the sequential CV loop skipped them.
fn build_folds(
    ds: &Dataset,
    volumes_as_weights: bool,
    folds: usize,
    rng: &mut Pcg64,
) -> Vec<FoldEval> {
    let kf = KFold::new(ds, folds, rng);
    let mut out = Vec::with_capacity(kf.k());
    for f in 0..kf.k() {
        let (tr, va) = kf.fold(ds, f);
        if tr.n_pos() == 0 || tr.n_neg() == 0 || va.is_empty() {
            continue;
        }
        let w = volumes_as_weights.then(|| tr.volumes.clone());
        let dists = DistanceCache::fits(tr.len()).then(|| DistanceCache::new(&tr.points));
        out.push(FoldEval { tr, va, w, dists });
    }
    out
}

/// Evaluate one candidate over the shared folds.
/// Returns (mean G-mean, mean SV fraction, successful trainings) — the SV
/// fraction is the tie-breaker: among near-equal candidates the sparser
/// model generalizes better and keeps the multilevel SV-neighborhood
/// expansion small.
fn cv_gmean(folds: &[FoldEval], params: &SvmParams) -> (f64, f64, usize) {
    let mut total = 0.0;
    let mut sv_frac = 0.0;
    let mut used = 0usize;
    let mut evals = 0usize;
    for fe in folds {
        // Trial trainings are bounded: a pathological (C, γ) candidate
        // must not stall the whole search — an early-stopped model scores
        // poorly and is discarded by the design anyway.
        let mut trial = *params;
        trial.max_iter = (50 * fe.tr.len()).clamp(10_000, 300_000);
        let trained = match &fe.dists {
            Some(d) => {
                train_weighted_cached(&fe.tr.points, &fe.tr.labels, &trial, fe.w.as_deref(), d)
            }
            None => train_weighted(&fe.tr.points, &fe.tr.labels, &trial, fe.w.as_deref()),
        };
        let model = match trained {
            Ok(m) => m,
            Err(_) => continue,
        };
        evals += 1;
        let m: Metrics = crate::metrics::evaluate(&model, &fe.va);
        total += m.gmean();
        sv_frac += model.n_sv() as f64 / fe.tr.len().max(1) as f64;
        used += 1;
    }
    if used == 0 {
        (0.0, 1.0, evals)
    } else {
        (total / used as f64, sv_frac / used as f64, evals)
    }
}

/// Tolerance within which two CV G-means count as a tie (SV-sparsity
/// breaks the tie).
const GMEAN_TIE: f64 = 5e-3;

fn resolve_params(
    cfg: &UdSearchConfig,
    log2c: f64,
    log2g: f64,
    ratio_mult: f64,
    imbalance_ratio: f64,
) -> SvmParams {
    let c = log2c.exp2();
    let (c_pos, c_neg) = match cfg.weights {
        WeightScheme::Balanced => (c * imbalance_ratio * ratio_mult, c),
        WeightScheme::Equal => (c, c),
    };
    SvmParams {
        c_pos,
        c_neg,
        kernel: KernelKind::Rbf {
            gamma: log2g.exp2(),
        },
        ..cfg.base
    }
}

/// Run the two-stage UD search.
///
/// `volumes_as_weights` switches per-instance C scaling to the dataset's
/// AMG volumes (used at coarse levels). `center` re-centers stage 1 on
/// inherited (log₂C, log₂γ) with a contracted box.
pub fn ud_search(
    ds: &Dataset,
    volumes_as_weights: bool,
    cfg: &UdSearchConfig,
    center: Option<(f64, f64)>,
    rng: &mut Pcg64,
) -> Result<UdSearchOutcome> {
    ud_search_with_ratio(ds, volumes_as_weights, cfg, center, None, rng)
}

/// Like [`ud_search`] but with an explicit C⁺/C⁻ coupling ratio.
///
/// The multilevel trainer computes the imbalance ratio once from the
/// *finest* class sizes and passes it to every level's search: refinement
/// levels train on boundary-biased subsets whose local class ratio says
/// nothing about the deployment distribution, so re-deriving the ratio
/// locally would drift the boundary toward the majority (the paper
/// inherits C⁺ and C⁻ through the hierarchy for the same reason).
pub fn ud_search_with_ratio(
    ds: &Dataset,
    volumes_as_weights: bool,
    cfg: &UdSearchConfig,
    center: Option<(f64, f64)>,
    ratio_override: Option<f64>,
    rng: &mut Pcg64,
) -> Result<UdSearchOutcome> {
    // The C⁺/C⁻ coupling must reflect the *mass* each class carries: at
    // coarse AMG levels a majority aggregate stands for many fine points
    // (its volume), so counting points would erase the imbalance
    // correction exactly where WSVM needs it.
    let (mass_pos, mass_neg) = if volumes_as_weights {
        let mut mp = 0.0;
        let mut mn = 0.0;
        for (i, &l) in ds.labels.iter().enumerate() {
            if l == 1 {
                mp += ds.volumes[i];
            } else {
                mn += ds.volumes[i];
            }
        }
        (mp.max(1e-12), mn.max(1e-12))
    } else {
        (ds.n_pos().max(1) as f64, ds.n_neg().max(1) as f64)
    };
    let imbalance_ratio = ratio_override.unwrap_or(mass_neg / mass_pos);
    // Fold split + per-fold shared context (distance caches) — drawn once,
    // before any trial, so the RNG stream is schedule-independent.
    let folds = build_folds(ds, volumes_as_weights, cfg.folds, rng);

    let full_center = (
        0.5 * (cfg.log2c.0 + cfg.log2c.1),
        0.5 * (cfg.log2g.0 + cfg.log2g.1),
    );
    let full_radius = (
        0.5 * (cfg.log2c.1 - cfg.log2c.0),
        0.5 * (cfg.log2g.1 - cfg.log2g.0),
    );
    let (c1, r1) = match center {
        Some(c) => (
            c,
            (
                full_radius.0 * cfg.inherit_shrink,
                full_radius.1 * cfg.inherit_shrink,
            ),
        ),
        None => (full_center, full_radius),
    };

    let mut evals = 0usize;
    // (gmean, sv_frac, center, ratio)
    let mut best = (f64::NEG_INFINITY, 1.0f64, c1, 1.0f64);
    // One stage: flatten the candidate × ratio grid into an ordered trial
    // list, score every trial on the pool (each is an independent pure
    // function of its triple over the shared folds), then reduce the
    // winner sequentially in ascending trial order — the same argmax the
    // sequential loop computed, so the result cannot depend on how the
    // trials were scheduled.
    let stage = |pts: &[(f64, f64)],
                 best: &mut (f64, f64, (f64, f64), f64),
                 evals: &mut usize,
                 trace: &mut Vec<f64>| {
        let trials: Vec<(f64, f64, f64)> = pts
            .iter()
            .flat_map(|&(lc, lg)| cfg.weight_ratio_grid.iter().map(move |&rm| (lc, lg, rm)))
            .collect();
        #[derive(Clone, Default)]
        struct TrialScore {
            gmean: f64,
            sv_frac: f64,
            evals: usize,
        }
        let scores = pool::parallel_map(trials.len(), 1, |t| {
            let (lc, lg, rm) = trials[t];
            let params = resolve_params(cfg, lc, lg, rm, imbalance_ratio);
            let (gmean, sv_frac, evals) = cv_gmean(&folds, &params);
            TrialScore { gmean, sv_frac, evals }
        });
        for (t, s) in scores.iter().enumerate() {
            *evals += s.evals;
            trace.push(s.gmean);
            let better = s.gmean > best.0 + GMEAN_TIE
                || ((s.gmean - best.0).abs() <= GMEAN_TIE && s.sv_frac < best.1);
            if better {
                let (lc, lg, rm) = trials[t];
                *best = (s.gmean.max(best.0), s.sv_frac, (lc, lg), rm);
            }
        }
    };

    let mut trial_gmeans = Vec::new();
    let s1 = scale_to(&ud_points(cfg.stage1_points), c1, r1);
    stage(&s1, &mut best, &mut evals, &mut trial_gmeans);
    // Stage 2: contract around the winner.
    let r2 = (r1.0 * 0.35, r1.1 * 0.35);
    let s2 = scale_to(&ud_points(cfg.stage2_points), best.2, r2);
    stage(&s2, &mut best, &mut evals, &mut trial_gmeans);

    let (gmean, _, centre, ratio) = best;
    let params = resolve_params(cfg, centre.0, centre.1, ratio, imbalance_ratio);
    Ok(UdSearchOutcome {
        params,
        gmean: gmean.max(0.0),
        center: centre,
        evaluations: evals,
        trial_gmeans,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::two_gaussians;

    fn quick_cfg() -> UdSearchConfig {
        UdSearchConfig {
            stage1_points: 5,
            stage2_points: 5,
            folds: 2,
            ..Default::default()
        }
    }

    #[test]
    fn finds_good_parameters_on_easy_data() {
        let mut rng = Pcg64::seed_from(61);
        let ds = two_gaussians(150, 60, 4, 4.0, &mut rng);
        let out = ud_search(&ds, false, &quick_cfg(), None, &mut rng).unwrap();
        assert!(out.gmean > 0.9, "gmean={}", out.gmean);
        assert!(out.evaluations > 0);
        assert!(out.params.c_pos > out.params.c_neg, "balanced coupling");
        // one recorded G-mean per trial: (stage1 + stage2) × ratio grid
        assert_eq!(out.trial_gmeans.len(), 5 + 5);
    }

    #[test]
    fn inherited_center_contracts_search() {
        let mut rng = Pcg64::seed_from(62);
        let ds = two_gaussians(120, 50, 3, 3.0, &mut rng);
        let cfg = quick_cfg();
        let out = ud_search(&ds, false, &cfg, Some((0.0, -2.0)), &mut rng).unwrap();
        // All candidates lie inside the contracted box: winner within
        // center ± shrink*full_radius ± stage-2 contraction (bounded).
        let full_r_c = 0.5 * (cfg.log2c.1 - cfg.log2c.0);
        assert!(
            (out.center.0 - 0.0).abs() <= full_r_c * cfg.inherit_shrink * 1.35 + 1e-9,
            "center {:?} escaped inherited box",
            out.center
        );
    }

    #[test]
    fn equal_weights_scheme_sets_cpos_eq_cneg() {
        let mut rng = Pcg64::seed_from(63);
        let ds = two_gaussians(80, 40, 3, 3.0, &mut rng);
        let cfg = UdSearchConfig {
            weights: WeightScheme::Equal,
            ..quick_cfg()
        };
        let out = ud_search(&ds, false, &cfg, None, &mut rng).unwrap();
        assert!((out.params.c_pos - out.params.c_neg).abs() < 1e-12);
    }

    #[test]
    fn weight_ratio_grid_expands_evaluations() {
        let mut rng = Pcg64::seed_from(64);
        let ds = two_gaussians(80, 40, 3, 3.0, &mut rng);
        let mut cfg = quick_cfg();
        let base = ud_search(&ds, false, &cfg, None, &mut rng).unwrap();
        cfg.weight_ratio_grid = vec![0.5, 1.0, 2.0];
        let wide = ud_search(&ds, false, &cfg, None, &mut rng).unwrap();
        assert!(wide.evaluations > base.evaluations);
    }
}
