//! Uniform-design point sets on the unit square.
//!
//! We use good-lattice-point (GLP) constructions: for a run size `n` and
//! generator `h` coprime with `n`, the design points are
//! `((2i+1)/(2n), (2·(i·h mod n)+1)/(2n))` — centered lattice points with
//! low discrepancy, the standard UD construction for 2 factors (cf. Fang &
//! Wang; Huang et al. use the published UD tables which coincide with GLP
//! sets at these sizes).

/// Generators giving low-discrepancy 2-factor designs for common run sizes.
fn generator_for(n: usize) -> usize {
    match n {
        5 => 2,
        7 => 3,
        9 => 4,
        11 => 7,
        13 => 5,
        17 => 10,
        19 => 8,
        21 => 13,
        25 => 11,
        _ => {
            // fall back to the golden-ratio multiplier rounded to coprime
            let mut h = ((n as f64) * 0.618_033_988_75).round() as usize;
            while gcd(h, n) != 1 {
                h += 1;
            }
            h
        }
    }
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// `n` UD points in the unit square `[0,1]²`.
pub fn ud_points(n: usize) -> Vec<(f64, f64)> {
    let n = n.max(1);
    let h = generator_for(n);
    (0..n)
        .map(|i| {
            let u = (2 * i + 1) as f64 / (2 * n) as f64;
            let v = (2 * ((i * h) % n) + 1) as f64 / (2 * n) as f64;
            (u, v)
        })
        .collect()
}

/// Map unit-square design points into the rectangle
/// `[c.0 - r.0, c.0 + r.0] × [c.1 - r.1, c.1 + r.1]`.
pub fn scale_to(points: &[(f64, f64)], center: (f64, f64), radius: (f64, f64)) -> Vec<(f64, f64)> {
    points
        .iter()
        .map(|&(u, v)| {
            (
                center.0 + (2.0 * u - 1.0) * radius.0,
                center.1 + (2.0 * v - 1.0) * radius.1,
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn points_are_in_unit_square_and_distinct() {
        for n in [5usize, 9, 13, 30] {
            let pts = ud_points(n);
            assert_eq!(pts.len(), n);
            for &(u, v) in &pts {
                assert!((0.0..=1.0).contains(&u));
                assert!((0.0..=1.0).contains(&v));
            }
            // distinct first coordinates by construction
            let mut us: Vec<f64> = pts.iter().map(|p| p.0).collect();
            us.dedup();
            assert_eq!(us.len(), n);
        }
    }

    #[test]
    fn second_factor_covers_all_levels() {
        // GLP with gcd(h,n)=1 → second coordinate visits each level once.
        let pts = ud_points(13);
        let mut levels: Vec<usize> = pts
            .iter()
            .map(|&(_, v)| ((v * 26.0 - 1.0) / 2.0).round() as usize)
            .collect();
        levels.sort_unstable();
        assert_eq!(levels, (0..13).collect::<Vec<_>>());
    }

    #[test]
    fn low_discrepancy_vs_diagonal() {
        // UD points should fill space better than the diagonal design:
        // the minimum pairwise distance must exceed the diagonal's spacing
        // scaled expectation for a grid-like spread.
        let pts = ud_points(9);
        let mut min_d = f64::INFINITY;
        for i in 0..9 {
            for j in (i + 1)..9 {
                let dx = pts[i].0 - pts[j].0;
                let dy = pts[i].1 - pts[j].1;
                min_d = min_d.min((dx * dx + dy * dy).sqrt());
            }
        }
        assert!(min_d > 0.15, "min pairwise distance {min_d}");
    }

    #[test]
    fn scaling_maps_to_rectangle() {
        let pts = scale_to(&ud_points(9), (2.0, -3.0), (4.0, 1.0));
        for &(x, y) in &pts {
            assert!((-2.0..=6.0).contains(&x));
            assert!((-4.0..=-2.0).contains(&y));
        }
    }
}
