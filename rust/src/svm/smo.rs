//! SMO solver for the (weighted) C-SVC dual — the LibSVM-3.20 equivalent
//! the paper uses for all small-scale trainings inside the refinement.
//!
//! Solves
//!
//! ```text
//! min_α  ½ αᵀQα − eᵀα    s.t.  yᵀα = 0,  0 ≤ α_i ≤ C_i
//! ```
//!
//! with `Q_ij = y_i y_j K(x_i, x_j)`, `C_i = C⁺` for minority points and
//! `C⁻` for majority points (Eq. 2 of the paper — WSVM), optionally scaled
//! by per-instance weights (used to honor AMG aggregate volumes at coarse
//! levels). Working pairs are chosen by second-order selection (WSS2,
//! Fan–Chen–Lin 2005), exactly LibSVM's default; shrinking bounds the
//! active set with full-gradient reconstruction before the final
//! convergence check.

use crate::data::matrix::Matrix;
use crate::error::{Error, Result};
use crate::svm::cache::KernelCache;
use crate::svm::kernel::{KernelKind, RowBackend, RustRowBackend};
use crate::svm::model::SvmModel;

/// Training parameters.
#[derive(Clone, Copy, Debug)]
pub struct SvmParams {
    /// Penalty for the minority (+1) class.
    pub c_pos: f64,
    /// Penalty for the majority (−1) class.
    pub c_neg: f64,
    /// Kernel.
    pub kernel: KernelKind,
    /// KKT violation tolerance (LibSVM default 1e-3).
    pub eps: f64,
    /// Iteration cap (defense against degenerate problems).
    pub max_iter: usize,
    /// Kernel cache budget in bytes.
    pub cache_bytes: usize,
    /// Enable shrinking.
    pub shrinking: bool,
}

impl Default for SvmParams {
    fn default() -> Self {
        SvmParams {
            c_pos: 1.0,
            c_neg: 1.0,
            kernel: KernelKind::Rbf { gamma: 0.5 },
            eps: 1e-3,
            max_iter: 10_000_000,
            cache_bytes: 128 << 20,
            shrinking: true,
        }
    }
}

/// Raw solver output.
#[derive(Debug)]
pub struct SolveResult {
    /// α per training point.
    pub alpha: Vec<f64>,
    /// Bias term ρ (decision = Σ y_iα_iK(x_i,·) − ρ).
    pub rho: f64,
    /// SMO iterations executed.
    pub iterations: usize,
    /// Final KKT gap.
    pub gap: f64,
    /// Kernel-cache hits during the solve.
    pub cache_hits: u64,
    /// Kernel-cache misses during the solve.
    pub cache_misses: u64,
    /// Whether the solve was seeded from a caller-provided α.
    pub warm_started: bool,
}

const TAU: f64 = 1e-12;

/// Kernel rows per chunk when (re)building gradients from a batched
/// backend call (bounds the staging buffer to `GRAD_CHUNK * n` floats).
const GRAD_CHUNK: usize = 32;

struct Solver<'a> {
    backend: &'a dyn RowBackend,
    cache: KernelCache<'a>,
    y: Vec<f64>,
    c: Vec<f64>,
    alpha: Vec<f64>,
    grad: Vec<f64>,
    kdiag: Vec<f64>,
    active: Vec<usize>,
    eps: f64,
    shrinking: bool,
    unshrunk: bool,
}

impl<'a> Solver<'a> {
    fn new(
        backend: &'a dyn RowBackend,
        labels: &[i8],
        params: &SvmParams,
        weights: Option<&[f64]>,
        alpha0: Option<&[f64]>,
    ) -> Result<Solver<'a>> {
        let n = backend.len();
        if labels.len() != n {
            return Err(Error::invalid("smo: label/point count mismatch"));
        }
        let y: Vec<f64> = labels.iter().map(|&l| l as f64).collect();
        let mut c: Vec<f64> = labels
            .iter()
            .map(|&l| if l == 1 { params.c_pos } else { params.c_neg })
            .collect();
        if let Some(w) = weights {
            if w.len() != n {
                return Err(Error::invalid("smo: weight count mismatch"));
            }
            for (ci, &wi) in c.iter_mut().zip(w) {
                *ci *= wi.max(1e-12);
            }
        }
        let cache = KernelCache::new(backend, params.cache_bytes);
        // K diagonal (O(n·d) via the backend's direct form).
        let mut kdiag = vec![0.0f64; n];
        backend.fill_diag(&mut kdiag);
        let mut solver = Solver {
            backend,
            cache,
            y,
            c,
            // α = 0 → G = −e.
            alpha: vec![0.0; n],
            grad: vec![-1.0f64; n],
            kdiag,
            active: (0..n).collect(),
            eps: params.eps,
            shrinking: params.shrinking,
            unshrunk: false,
        };
        if let Some(a0) = alpha0 {
            if a0.len() != n {
                return Err(Error::invalid("smo: warm-start alpha count mismatch"));
            }
            solver.seed_alpha(a0);
        }
        Ok(solver)
    }

    /// Seed α from a caller-provided vector: clip to the box constraints,
    /// repair the equality constraint yᵀα = 0 (SMO pair updates preserve
    /// it, so a violated start would never converge to a feasible point),
    /// and rebuild the gradient from the nonzero entries with batched
    /// kernel rows.
    fn seed_alpha(&mut self, a0: &[f64]) {
        let n = a0.len();
        for t in 0..n {
            self.alpha[t] = a0[t].clamp(0.0, self.c[t]);
        }
        // Repair yᵀα = 0 by draining mass from the surplus side (the
        // side's total is at least |s|, so this always terminates at 0).
        let mut s: f64 = self.alpha.iter().zip(&self.y).map(|(a, y)| a * y).sum();
        for t in 0..n {
            if s.abs() <= 1e-12 {
                break;
            }
            if self.y[t] * s > 0.0 && self.alpha[t] > 0.0 {
                let take = self.alpha[t].min(s.abs());
                self.alpha[t] -= take;
                s -= self.y[t] * take;
            }
        }
        self.rebuild_gradient_from_alpha();
    }

    /// G_t = −1 + Σ_j y_t y_j α_j K_tj, accumulated from batched kernel
    /// rows of the nonzero-α points only (O(#SV · n) kernel work, done
    /// tile-parallel by the backend instead of row-at-a-time).
    ///
    /// When the SV set fits in the kernel cache the rows go through
    /// [`KernelCache::rows_batch`], so resident rows are reused, misses
    /// are grouped into parallel batches, and the hit/miss counters see
    /// the traffic; larger sets stream straight from the backend in
    /// bounded chunks (caching them would just thrash).
    fn rebuild_gradient_from_alpha(&mut self) {
        let n = self.alpha.len();
        self.grad.clear();
        self.grad.resize(n, -1.0);
        let sv: Vec<usize> = (0..n).filter(|&j| self.alpha[j] > 0.0).collect();
        if sv.is_empty() {
            return;
        }
        if sv.len() <= self.cache.capacity_rows() {
            self.cache.rows_batch(&sv);
            for &j in &sv {
                let aj = self.alpha[j] * self.y[j];
                let row = self.cache.row(j);
                for t in 0..n {
                    self.grad[t] += self.y[t] * aj * row[t] as f64;
                }
            }
        } else {
            let mut buf = vec![0.0f32; GRAD_CHUNK.min(sv.len()) * n];
            for chunk in sv.chunks(GRAD_CHUNK) {
                let out = &mut buf[..chunk.len() * n];
                self.backend.fill_rows_batch(chunk, out);
                for (k, &j) in chunk.iter().enumerate() {
                    let aj = self.alpha[j] * self.y[j];
                    let row = &out[k * n..(k + 1) * n];
                    for t in 0..n {
                        self.grad[t] += self.y[t] * aj * row[t] as f64;
                    }
                }
            }
        }
    }

    /// −y_t G_t, the WSS score.
    #[inline]
    fn score(&self, t: usize) -> f64 {
        -self.y[t] * self.grad[t]
    }

    #[inline]
    fn in_up(&self, t: usize) -> bool {
        (self.y[t] > 0.0 && self.alpha[t] < self.c[t]) || (self.y[t] < 0.0 && self.alpha[t] > 0.0)
    }

    #[inline]
    fn in_low(&self, t: usize) -> bool {
        (self.y[t] < 0.0 && self.alpha[t] < self.c[t]) || (self.y[t] > 0.0 && self.alpha[t] > 0.0)
    }

    /// WSS2: returns (i, j) or None when converged on the active set.
    fn select_working_pair(&mut self) -> Option<(usize, usize)> {
        let mut i = usize::MAX;
        let mut m = f64::NEG_INFINITY;
        for &t in &self.active {
            if self.in_up(t) {
                let s = self.score(t);
                if s > m {
                    m = s;
                    i = t;
                }
            }
        }
        if i == usize::MAX {
            return None;
        }
        // Row i for the second-order term — borrowed from the cache, no
        // copy (the loop below touches only disjoint fields).
        let row_i = self.cache.row(i);

        let mut j = usize::MAX;
        let mut best_obj = f64::INFINITY;
        let mut m_low = f64::INFINITY;
        for &t in &self.active {
            let in_low = (self.y[t] < 0.0 && self.alpha[t] < self.c[t])
                || (self.y[t] > 0.0 && self.alpha[t] > 0.0);
            if in_low {
                let s = -self.y[t] * self.grad[t];
                m_low = m_low.min(s);
                let b = m - s;
                if b > 0.0 {
                    let a = self.kdiag[i] + self.kdiag[t]
                        - 2.0 * self.y[i] * self.y[t] * row_i[t] as f64;
                    let a = if a > 0.0 { a } else { TAU };
                    let obj = -(b * b) / a;
                    if obj < best_obj {
                        best_obj = obj;
                        j = t;
                    }
                }
            }
        }
        if m - m_low <= self.eps || j == usize::MAX {
            return None;
        }
        Some((i, j))
    }

    /// Two-variable analytic update (LibSVM's `Solver::solve` inner step).
    /// One `row_pair` fetch serves both the k_ij read and the gradient
    /// pass — the alpha/grad mutations touch fields disjoint from the
    /// cache, so the row borrows stay live across the whole update.
    fn update_pair(&mut self, i: usize, j: usize) {
        let (row_i, row_j) = self.cache.row_pair(i, j);
        let yi = self.y[i];
        let yj = self.y[j];
        let ci = self.c[i];
        let cj = self.c[j];
        let kii = self.kdiag[i];
        let kjj = self.kdiag[j];
        let kij = row_i[j] as f64;
        let old_ai = self.alpha[i];
        let old_aj = self.alpha[j];

        if yi != yj {
            let quad = (kii + kjj + 2.0 * kij).max(TAU);
            let delta = (-self.grad[i] - self.grad[j]) / quad;
            let diff = old_ai - old_aj;
            self.alpha[i] += delta;
            self.alpha[j] += delta;
            if diff > 0.0 {
                if self.alpha[j] < 0.0 {
                    self.alpha[j] = 0.0;
                    self.alpha[i] = diff;
                }
            } else if self.alpha[i] < 0.0 {
                self.alpha[i] = 0.0;
                self.alpha[j] = -diff;
            }
            if diff > ci - cj {
                if self.alpha[i] > ci {
                    self.alpha[i] = ci;
                    self.alpha[j] = ci - diff;
                }
            } else if self.alpha[j] > cj {
                self.alpha[j] = cj;
                self.alpha[i] = cj + diff;
            }
        } else {
            let quad = (kii + kjj - 2.0 * kij).max(TAU);
            let delta = (self.grad[i] - self.grad[j]) / quad;
            let sum = old_ai + old_aj;
            self.alpha[i] -= delta;
            self.alpha[j] += delta;
            if sum > ci {
                if self.alpha[i] > ci {
                    self.alpha[i] = ci;
                    self.alpha[j] = sum - ci;
                }
            } else if self.alpha[j] < 0.0 {
                self.alpha[j] = 0.0;
                self.alpha[i] = sum;
            }
            if sum > cj {
                if self.alpha[j] > cj {
                    self.alpha[j] = cj;
                    self.alpha[i] = sum - cj;
                }
            } else if self.alpha[i] < 0.0 {
                self.alpha[i] = 0.0;
                self.alpha[j] = sum;
            }
        }

        // Gradient update over the active set: G_t += Q_ti Δα_i + Q_tj Δα_j.
        let dai = self.alpha[i] - old_ai;
        let daj = self.alpha[j] - old_aj;
        if dai == 0.0 && daj == 0.0 {
            return;
        }
        for &t in &self.active {
            self.grad[t] +=
                self.y[t] * (yi * row_i[t] as f64 * dai + yj * row_j[t] as f64 * daj);
        }
    }

    /// Reconstruct the full gradient from scratch (after shrinking, before
    /// the final convergence check). O(#SV · n) kernel work, batched
    /// through the backend's tiled parallel path.
    fn reconstruct_gradient(&mut self) {
        let n = self.cache.n();
        self.rebuild_gradient_from_alpha();
        self.active = (0..n).collect();
    }

    /// KKT gap on the active set.
    fn gap(&self) -> f64 {
        let mut m_up = f64::NEG_INFINITY;
        let mut m_low = f64::INFINITY;
        for &t in &self.active {
            if self.in_up(t) {
                m_up = m_up.max(self.score(t));
            }
            if self.in_low(t) {
                m_low = m_low.min(self.score(t));
            }
        }
        m_up - m_low
    }

    /// ρ from free SVs (LibSVM `calculate_rho`).
    fn rho(&self) -> f64 {
        let n = self.cache.n();
        let mut n_free = 0usize;
        let mut sum_free = 0.0;
        let mut ub = f64::INFINITY;
        let mut lb = f64::NEG_INFINITY;
        for t in 0..n {
            let ygt = self.y[t] * self.grad[t];
            if self.alpha[t] >= self.c[t] {
                if self.y[t] < 0.0 {
                    ub = ub.min(ygt);
                } else {
                    lb = lb.max(ygt);
                }
            } else if self.alpha[t] <= 0.0 {
                if self.y[t] > 0.0 {
                    ub = ub.min(ygt);
                } else {
                    lb = lb.max(ygt);
                }
            } else {
                n_free += 1;
                sum_free += ygt;
            }
        }
        if n_free > 0 {
            sum_free / n_free as f64
        } else {
            (ub + lb) / 2.0
        }
    }

    fn solve(&mut self, max_iter: usize) -> (usize, f64) {
        let n = self.cache.n();
        let shrink_every = n.min(1000).max(1);
        let mut iter = 0usize;
        let mut counter = shrink_every;
        loop {
            if iter >= max_iter {
                break;
            }
            counter -= 1;
            if counter == 0 {
                counter = shrink_every;
                if self.shrinking && !self.unshrunk {
                    self.shrink_simple();
                }
            }
            match self.select_working_pair() {
                Some((i, j)) => {
                    self.update_pair(i, j);
                    iter += 1;
                }
                None => {
                    // Converged on the active set: if shrunk, reconstruct
                    // and re-check on the full problem.
                    if self.active.len() < n {
                        self.reconstruct_gradient();
                        self.unshrunk = true;
                        continue;
                    }
                    break;
                }
            }
        }
        (iter, self.gap())
    }

    /// Simple, conservative shrinking rule: drop variables that are at a
    /// bound and whose score is strictly inside the current (m_up, m_low)
    /// bracket by a margin (they cannot be selected while the bracket
    /// holds). Correctness is preserved by the final full-gradient
    /// reconstruction + re-check in `solve`.
    fn shrink_simple(&mut self) {
        let mut m_up = f64::NEG_INFINITY;
        let mut m_low = f64::INFINITY;
        for &t in &self.active {
            if self.in_up(t) {
                m_up = m_up.max(self.score(t));
            }
            if self.in_low(t) {
                m_low = m_low.min(self.score(t));
            }
        }
        if !(m_up.is_finite() && m_low.is_finite()) {
            return;
        }
        let keep: Vec<usize> = self
            .active
            .iter()
            .copied()
            .filter(|&t| {
                let at_lower = self.alpha[t] <= 0.0;
                let at_upper = self.alpha[t] >= self.c[t];
                if !(at_lower || at_upper) {
                    return true; // free variables stay
                }
                let s = self.score(t);
                // Candidate for selection only while s > m_low (as an up
                // member) or s < m_up (as a low member). Keep if it could
                // still participate.
                let could_up = self.in_up(t) && s > m_low;
                let could_low = self.in_low(t) && s < m_up;
                could_up || could_low
            })
            .collect();
        if keep.len() >= 2 {
            self.active = keep;
        }
    }
}

/// Solve the dual on an arbitrary row backend. `weights` optionally scales
/// each point's C (AMG volumes).
pub fn solve(
    backend: &dyn RowBackend,
    labels: &[i8],
    params: &SvmParams,
    weights: Option<&[f64]>,
) -> Result<SolveResult> {
    solve_warm(backend, labels, params, weights, None)
}

/// Like [`solve`], but optionally warm-started: `alpha0` seeds the dual
/// variables (clipped to the box constraints, equality-constraint
/// repaired, gradient reconstructed from batched kernel rows of the
/// nonzero entries). The fixed point is the same as a cold start — only
/// the iteration count changes.
pub fn solve_warm(
    backend: &dyn RowBackend,
    labels: &[i8],
    params: &SvmParams,
    weights: Option<&[f64]>,
    alpha0: Option<&[f64]>,
) -> Result<SolveResult> {
    if backend.len() == 0 {
        return Err(Error::Degenerate("empty training set".into()));
    }
    if !labels.contains(&1) || !labels.contains(&-1) {
        return Err(Error::Degenerate("training set has a single class".into()));
    }
    let warm_started = alpha0.map(|a| a.iter().any(|&v| v > 0.0)).unwrap_or(false);
    let mut solver = Solver::new(backend, labels, params, weights, alpha0)?;
    let (iterations, gap) = solver.solve(params.max_iter);
    let rho = solver.rho();
    let (cache_hits, cache_misses) = solver.cache.stats();
    Ok(SolveResult {
        alpha: solver.alpha,
        rho,
        iterations,
        gap,
        cache_hits,
        cache_misses,
        warm_started,
    })
}

/// Solver-side statistics of one training run (surfaced per level by the
/// multilevel trainer and the coordinator report).
#[derive(Clone, Copy, Debug, Default)]
pub struct TrainStats {
    /// SMO iterations executed.
    pub iterations: usize,
    /// Final KKT gap.
    pub gap: f64,
    /// Kernel-cache hits.
    pub cache_hits: u64,
    /// Kernel-cache misses.
    pub cache_misses: u64,
    /// Whether the solve was seeded from an inherited α.
    pub warm_started: bool,
}

impl TrainStats {
    /// Cache hit fraction in [0, 1] (0 when no accesses happened).
    pub fn hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

/// Train a (weighted) SVM on dense points with the pure-rust backend and
/// package the result as a model.
pub fn train_weighted(
    points: &Matrix,
    labels: &[i8],
    params: &SvmParams,
    weights: Option<&[f64]>,
) -> Result<SvmModel> {
    train_weighted_warm(points, labels, params, weights, None).map(|(m, _)| m)
}

/// Like [`train_weighted`], but optionally warm-started from `alpha0`
/// (see [`solve_warm`]) and returning solver statistics alongside the
/// model.
pub fn train_weighted_warm(
    points: &Matrix,
    labels: &[i8],
    params: &SvmParams,
    weights: Option<&[f64]>,
    alpha0: Option<&[f64]>,
) -> Result<(SvmModel, TrainStats)> {
    let backend = RustRowBackend::new(points, params.kernel);
    let res = solve_warm(&backend, labels, params, weights, alpha0)?;
    let stats = TrainStats {
        iterations: res.iterations,
        gap: res.gap,
        cache_hits: res.cache_hits,
        cache_misses: res.cache_misses,
        warm_started: res.warm_started,
    };
    let model = SvmModel::from_solution(points, labels, &res.alpha, res.rho, params);
    Ok((model, stats))
}

/// Like [`train_weighted`], but with the kernel geometry served from a
/// shared [`DistanceCache`](crate::svm::dist::DistanceCache) (model
/// selection computes `d²` once per CV fold; every `(C, γ)` trial then
/// pays only the `exp` pass). The cache must cover exactly `points`.
pub fn train_weighted_cached(
    points: &Matrix,
    labels: &[i8],
    params: &SvmParams,
    weights: Option<&[f64]>,
    dists: &crate::svm::dist::DistanceCache,
) -> Result<SvmModel> {
    let backend = RustRowBackend::with_distances(points, params.kernel, dists);
    let res = solve_warm(&backend, labels, params, weights, None)?;
    Ok(SvmModel::from_solution(
        points, labels, &res.alpha, res.rho, params,
    ))
}

/// Train an unweighted SVM (C⁺ = C⁻ = params.c_pos = params.c_neg).
pub fn train(points: &Matrix, labels: &[i8], params: &SvmParams) -> Result<SvmModel> {
    train_weighted(points, labels, params, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::two_gaussians;
    use crate::util::rng::Pcg64;

    fn params_rbf(gamma: f64, c: f64) -> SvmParams {
        SvmParams {
            c_pos: c,
            c_neg: c,
            kernel: KernelKind::Rbf { gamma },
            ..Default::default()
        }
    }

    #[test]
    fn separable_problem_trains_perfectly() {
        let mut rng = Pcg64::seed_from(41);
        let ds = two_gaussians(80, 80, 2, 8.0, &mut rng);
        let model = train(&ds.points, &ds.labels, &params_rbf(0.5, 10.0)).unwrap();
        let mut correct = 0;
        for i in 0..ds.len() {
            if model.predict_label(ds.points.row(i)) == ds.labels[i] {
                correct += 1;
            }
        }
        assert_eq!(correct, ds.len(), "separable data must be fit exactly");
    }

    #[test]
    fn alphas_respect_box_constraints() {
        let mut rng = Pcg64::seed_from(42);
        let ds = two_gaussians(60, 60, 3, 1.0, &mut rng); // overlapping
        let p = params_rbf(0.3, 2.0);
        let backend = RustRowBackend::new(&ds.points, p.kernel);
        let res = solve(&backend, &ds.labels, &p, None).unwrap();
        for (i, &a) in res.alpha.iter().enumerate() {
            assert!(a >= -1e-12 && a <= 2.0 + 1e-9, "alpha[{i}]={a}");
        }
        // equality constraint
        let sum: f64 = res
            .alpha
            .iter()
            .zip(&ds.labels)
            .map(|(&a, &y)| a * y as f64)
            .sum();
        assert!(sum.abs() < 1e-6, "yᵀα = {sum}");
    }

    #[test]
    fn kkt_gap_below_eps() {
        let mut rng = Pcg64::seed_from(43);
        let ds = two_gaussians(100, 40, 4, 2.0, &mut rng);
        let p = params_rbf(0.25, 1.0);
        let backend = RustRowBackend::new(&ds.points, p.kernel);
        let res = solve(&backend, &ds.labels, &p, None).unwrap();
        assert!(res.gap <= p.eps + 1e-9, "gap {} > eps", res.gap);
    }

    #[test]
    fn weighted_classes_shift_the_boundary() {
        // Heavily imbalanced overlapping data: with C+ ≫ C- the minority
        // recall (sensitivity) must improve vs equal weights.
        let mut rng = Pcg64::seed_from(44);
        let ds = two_gaussians(400, 40, 2, 2.0, &mut rng);
        let eq = train(&ds.points, &ds.labels, &params_rbf(0.5, 1.0)).unwrap();
        let mut wp = params_rbf(0.5, 1.0);
        wp.c_pos = 10.0;
        let weighted = train_weighted(&ds.points, &ds.labels, &wp, None).unwrap();
        let recall = |m: &SvmModel| {
            let mut tp = 0;
            let mut p = 0;
            for i in 0..ds.len() {
                if ds.labels[i] == 1 {
                    p += 1;
                    if m.predict_label(ds.points.row(i)) == 1 {
                        tp += 1;
                    }
                }
            }
            tp as f64 / p as f64
        };
        assert!(
            recall(&weighted) >= recall(&eq),
            "weighting must not hurt minority recall"
        );
        assert!(recall(&weighted) > 0.6);
    }

    #[test]
    fn instance_weights_scale_box() {
        let mut rng = Pcg64::seed_from(45);
        let ds = two_gaussians(50, 50, 2, 1.5, &mut rng);
        let p = params_rbf(0.5, 1.0);
        let w: Vec<f64> = (0..100).map(|i| if i < 50 { 3.0 } else { 1.0 }).collect();
        let backend = RustRowBackend::new(&ds.points, p.kernel);
        let res = solve(&backend, &ds.labels, &p, Some(&w)).unwrap();
        for i in 0..100 {
            let cap = if i < 50 { 3.0 } else { 1.0 };
            assert!(res.alpha[i] <= cap + 1e-9);
        }
    }

    #[test]
    fn degenerate_inputs_error() {
        let m = Matrix::from_vec(2, 1, vec![0.0, 1.0]).unwrap();
        assert!(train(&m, &[1, 1], &SvmParams::default()).is_err());
    }

    #[test]
    fn warm_start_from_solution_converges_immediately_to_same_answer() {
        let mut rng = Pcg64::seed_from(48);
        let ds = two_gaussians(120, 60, 3, 2.0, &mut rng);
        let p = params_rbf(0.3, 1.5);
        let backend = RustRowBackend::new(&ds.points, p.kernel);
        let cold = solve(&backend, &ds.labels, &p, None).unwrap();
        let warm = solve_warm(&backend, &ds.labels, &p, None, Some(&cold.alpha)).unwrap();
        assert!(warm.warm_started);
        assert!(
            warm.iterations <= cold.iterations,
            "warm {} vs cold {}",
            warm.iterations,
            cold.iterations
        );
        assert!((warm.rho - cold.rho).abs() < 5e-3, "{} vs {}", warm.rho, cold.rho);
        let diff: f64 = warm
            .alpha
            .iter()
            .zip(&cold.alpha)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
            / cold.alpha.len() as f64;
        assert!(diff < 1e-3, "mean |Δα| = {diff}");
        assert!(warm.gap <= p.eps + 1e-9);
    }

    #[test]
    fn warm_start_from_garbage_is_repaired_and_converges() {
        let mut rng = Pcg64::seed_from(49);
        let ds = two_gaussians(80, 50, 3, 2.0, &mut rng);
        let p = params_rbf(0.4, 2.0);
        let backend = RustRowBackend::new(&ds.points, p.kernel);
        // out-of-box, equality-violating seed: must be clipped + repaired
        let bad: Vec<f64> = (0..ds.len()).map(|i| (i as f64 * 0.37) % 5.0 - 1.0).collect();
        let warm = solve_warm(&backend, &ds.labels, &p, None, Some(&bad)).unwrap();
        let cold = solve(&backend, &ds.labels, &p, None).unwrap();
        for (i, &a) in warm.alpha.iter().enumerate() {
            assert!(a >= -1e-12 && a <= 2.0 + 1e-9, "alpha[{i}]={a}");
        }
        let sum: f64 = warm
            .alpha
            .iter()
            .zip(&ds.labels)
            .map(|(&a, &y)| a * y as f64)
            .sum();
        assert!(sum.abs() < 1e-6, "yᵀα = {sum}");
        assert!(warm.gap <= p.eps + 1e-9);
        assert!((warm.rho - cold.rho).abs() < 5e-2, "{} vs {}", warm.rho, cold.rho);
    }

    #[test]
    fn solve_reports_cache_traffic() {
        let mut rng = Pcg64::seed_from(50);
        let ds = two_gaussians(60, 60, 3, 1.5, &mut rng);
        let p = params_rbf(0.5, 1.0);
        let backend = RustRowBackend::new(&ds.points, p.kernel);
        let res = solve(&backend, &ds.labels, &p, None).unwrap();
        assert!(res.cache_misses > 0, "a cold solve must miss");
        assert!(res.cache_hits > 0, "SMO revisits working-set rows");
        assert!(!res.warm_started);
    }

    #[test]
    fn shrinking_matches_non_shrinking() {
        let mut rng = Pcg64::seed_from(46);
        let ds = two_gaussians(150, 60, 3, 2.0, &mut rng);
        let mut p = params_rbf(0.3, 1.5);
        p.shrinking = true;
        let a = train_weighted(&ds.points, &ds.labels, &p, None).unwrap();
        p.shrinking = false;
        let b = train_weighted(&ds.points, &ds.labels, &p, None).unwrap();
        // Decision values should agree closely on a probe set.
        let mut rng2 = Pcg64::seed_from(47);
        let probe = two_gaussians(20, 20, 3, 2.0, &mut rng2);
        for i in 0..probe.len() {
            let da = a.decision(probe.points.row(i));
            let db = b.decision(probe.points.row(i));
            assert!(
                (da - db).abs() < 5e-2 * da.abs().max(1.0),
                "shrink mismatch {da} vs {db}"
            );
        }
    }
}
