//! Kernel functions and batched kernel-row evaluation.
//!
//! All paper experiments use the Gaussian kernel
//! `K(x,y) = exp(-γ‖x−y‖²)`; linear and polynomial kernels are provided
//! for completeness (the paper's "omitted observations" discuss LibLINEAR
//! as a refinement alternative on easy data).
//!
//! Kernel *rows* are the hot path of SMO: `K(x_i, ·)` against the whole
//! training set. [`RowBackend`] abstracts who computes them — the portable
//! rust loops below, or the AOT Pallas/XLA artifact through
//! [`crate::runtime::rbf`] (L1/L2 of the three-layer stack).

use crate::data::matrix::{dot, sqdist, Matrix};
use crate::data::simd;
use crate::svm::dist::DistanceCache;
use crate::util::pool;

/// Column-tile width of the blocked kernel micro-kernel: kernel rows are
/// produced `KERNEL_TILE` points at a time so the tile of the point matrix
/// stays cache-resident while the (cheap) transcendental pass runs over it.
pub const KERNEL_TILE: usize = 256;

/// Number of requested rows each parallel task computes together. Rows in
/// one block share every point tile they stream through, so the point
/// matrix is read once per block instead of once per row.
const QUERY_BLOCK: usize = 4;

/// Kernel function over feature vectors.
pub trait Kernel: Send + Sync {
    /// K(a, b).
    fn eval(&self, a: &[f32], b: &[f32]) -> f64;

    /// K(x_i, x_j) given precomputed squared norms (RBF fast path uses
    /// `‖a‖² + ‖b‖² − 2a·b`; others ignore the norms).
    fn eval_with_norms(&self, a: &[f32], b: &[f32], _na: f64, _nb: f64) -> f64 {
        self.eval(a, b)
    }

    /// Human-readable parameterization (model files, logs).
    fn describe(&self) -> String;
}

/// Enumerated kernel configuration (serializable into model files).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelKind {
    /// exp(-γ‖x−y‖²)
    Rbf {
        /// Bandwidth γ.
        gamma: f64,
    },
    /// x·y
    Linear,
    /// (γ x·y + c)^d
    Poly {
        /// Scale γ.
        gamma: f64,
        /// Offset c.
        coef0: f64,
        /// Degree d.
        degree: u32,
    },
}

impl KernelKind {
    /// Instantiate the kernel object.
    pub fn build(&self) -> Box<dyn Kernel> {
        match *self {
            KernelKind::Rbf { gamma } => Box::new(RbfKernel { gamma }),
            KernelKind::Linear => Box::new(LinearKernel),
            KernelKind::Poly {
                gamma,
                coef0,
                degree,
            } => Box::new(PolyKernel {
                gamma,
                coef0,
                degree,
            }),
        }
    }

    /// The γ parameter if the kernel has one.
    pub fn gamma(&self) -> Option<f64> {
        match *self {
            KernelKind::Rbf { gamma } | KernelKind::Poly { gamma, .. } => Some(gamma),
            KernelKind::Linear => None,
        }
    }
}

/// Gaussian kernel.
#[derive(Clone, Copy, Debug)]
pub struct RbfKernel {
    /// Bandwidth γ.
    pub gamma: f64,
}

impl Kernel for RbfKernel {
    #[inline]
    fn eval(&self, a: &[f32], b: &[f32]) -> f64 {
        (-self.gamma * sqdist(a, b)).exp()
    }

    #[inline]
    fn eval_with_norms(&self, a: &[f32], b: &[f32], na: f64, nb: f64) -> f64 {
        let d2 = (na + nb - 2.0 * dot(a, b) as f64).max(0.0);
        (-self.gamma * d2).exp()
    }

    fn describe(&self) -> String {
        format!("rbf gamma={}", self.gamma)
    }
}

/// Linear kernel.
#[derive(Clone, Copy, Debug)]
pub struct LinearKernel;

impl Kernel for LinearKernel {
    #[inline]
    fn eval(&self, a: &[f32], b: &[f32]) -> f64 {
        dot(a, b) as f64
    }

    fn describe(&self) -> String {
        "linear".to_string()
    }
}

/// Polynomial kernel.
#[derive(Clone, Copy, Debug)]
pub struct PolyKernel {
    /// Scale γ.
    pub gamma: f64,
    /// Offset c.
    pub coef0: f64,
    /// Degree d.
    pub degree: u32,
}

impl Kernel for PolyKernel {
    #[inline]
    fn eval(&self, a: &[f32], b: &[f32]) -> f64 {
        (self.gamma * dot(a, b) as f64 + self.coef0).powi(self.degree as i32)
    }

    fn describe(&self) -> String {
        format!(
            "poly gamma={} coef0={} degree={}",
            self.gamma, self.coef0, self.degree
        )
    }
}

/// Batched kernel-row provider: fills `K(x_i, ·)` for the whole set.
pub trait RowBackend: Send + Sync {
    /// Number of data points.
    fn len(&self) -> usize;
    /// True if empty.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Compute the full kernel row of point `i` into `out` (length =
    /// `len()`), `out[j] = K(x_i, x_j)` as f32 (LibSVM precision).
    fn fill_row(&self, i: usize, out: &mut [f32]);

    /// Compute many kernel rows at once: `out` must hold
    /// `idxs.len() * len()` values and receives the full row of `idxs[k]`
    /// at `out[k*len()..(k+1)*len()]`. Backends override this with
    /// batched/parallel paths; the default is a sequential [`fill_row`]
    /// loop (exactly equivalent, used by backends that already hold a
    /// precomputed Gram matrix).
    ///
    /// [`fill_row`]: RowBackend::fill_row
    fn fill_rows_batch(&self, idxs: &[usize], out: &mut [f32]) {
        let n = self.len();
        assert_eq!(
            out.len(),
            idxs.len() * n,
            "fill_rows_batch: out holds {} values, need {} rows x {} points",
            out.len(),
            idxs.len(),
            n
        );
        for (k, &i) in idxs.iter().enumerate() {
            self.fill_row(i, &mut out[k * n..(k + 1) * n]);
        }
    }

    /// Kernel diagonal K(x_i, x_i) for all i. Default falls back to full
    /// rows (O(n²·d)); backends override with the O(n·d) direct form —
    /// SMO needs the diagonal at startup and the fallback dominates
    /// startup cost on large sets.
    fn fill_diag(&self, out: &mut [f64]) {
        let mut row = vec![0.0f32; self.len()];
        for i in 0..self.len() {
            self.fill_row(i, &mut row);
            out[i] = row[i] as f64;
        }
    }
}

/// Pure-rust backend with precomputed squared norms (the default; also the
/// reference the PJRT backend is validated against).
pub struct RustRowBackend<'a> {
    points: &'a Matrix,
    kind: KernelKind,
    norms: Vec<f64>,
    /// Optional shared squared-distance cache: when present, RBF rows skip
    /// the O(n·d) geometry pass and run only the `exp` pass over cached
    /// `d²` (model selection layers one cache under every candidate γ).
    dists: Option<&'a DistanceCache>,
}

impl<'a> RustRowBackend<'a> {
    /// Precompute norms and wrap the points.
    pub fn new(points: &'a Matrix, kind: KernelKind) -> Self {
        RustRowBackend {
            points,
            kind,
            norms: points.row_sqnorms(),
            dists: None,
        }
    }

    /// Like [`RustRowBackend::new`], but layered over a precomputed
    /// [`DistanceCache`] of the same points. Only [`KernelKind::Rbf`]
    /// consults the cache (γ is a pure transform of `d²`); other kernels
    /// evaluate directly. Panics if the cache size disagrees with the
    /// point count.
    pub fn with_distances(points: &'a Matrix, kind: KernelKind, dists: &'a DistanceCache) -> Self {
        assert_eq!(
            dists.len(),
            points.rows(),
            "with_distances: cache over {} points, matrix has {} rows",
            dists.len(),
            points.rows()
        );
        RustRowBackend {
            points,
            kind,
            norms: points.row_sqnorms(),
            dists: Some(dists),
        }
    }

    /// Tiled single-row micro-kernel: identical output to
    /// [`RowBackend::fill_row`], but blocked in [`KERNEL_TILE`]-point
    /// column tiles with the transcendental (`exp`/`powi`) hoisted into a
    /// separate pass over each tile. Exposed for the benchmark harness.
    pub fn fill_row_tiled(&self, i: usize, out: &mut [f32]) {
        self.fill_rows_block(&[i], out);
    }

    /// Blocked micro-kernel over a small set of requested rows: streams
    /// the point matrix tile by tile, reusing each tile across every row
    /// in the block, with precomputed norms and a separate
    /// transcendental pass per tile. The geometry pass runs through the
    /// dispatched [`simd::dot_rows`] micro-kernel over the contiguous
    /// tile panel — bit-identical to a per-point [`dot`] loop on every
    /// SIMD backend.
    fn fill_rows_block(&self, idxs: &[usize], out: &mut [f32]) {
        let n = self.points.rows();
        let d = self.points.cols();
        let pts = self.points.as_slice();
        debug_assert_eq!(out.len(), idxs.len() * n);
        let mut dots = [0.0f32; KERNEL_TILE];
        let mut t0 = 0usize;
        while t0 < n {
            let t1 = (t0 + KERNEL_TILE).min(n);
            // Rows t0..t1 are one contiguous row-major panel of the
            // point matrix: the multi-row dot kernel streams it once per
            // requested row while the panel stays cache-resident.
            let panel = &pts[t0 * d..t1 * d];
            for (k, &i) in idxs.iter().enumerate() {
                let a = self.points.row(i);
                let orow = &mut out[k * n..(k + 1) * n];
                match self.kind {
                    KernelKind::Rbf { gamma } => {
                        // pass 1: squared distances — copied from the
                        // shared cache when present (identical values: the
                        // cache stores exactly this pass's output), else
                        // via the norm identity
                        if let Some(c) = self.dists {
                            orow[t0..t1].copy_from_slice(&c.row(i)[t0..t1]);
                        } else {
                            let na = self.norms[i];
                            simd::dot_rows(a, panel, d, &mut dots[..t1 - t0]);
                            for j in t0..t1 {
                                let d2 =
                                    (na + self.norms[j] - 2.0 * dots[j - t0] as f64).max(0.0);
                                orow[j] = d2 as f32;
                            }
                        }
                        // pass 2: hoisted exp over the tile
                        for v in &mut orow[t0..t1] {
                            *v = (-gamma * *v as f64).exp() as f32;
                        }
                    }
                    KernelKind::Linear => {
                        simd::dot_rows(a, panel, d, &mut orow[t0..t1]);
                    }
                    KernelKind::Poly {
                        gamma,
                        coef0,
                        degree,
                    } => {
                        simd::dot_rows(a, panel, d, &mut orow[t0..t1]);
                        // pass 2: hoisted powi over the tile
                        for v in &mut orow[t0..t1] {
                            *v = (gamma * *v as f64 + coef0).powi(degree as i32) as f32;
                        }
                    }
                }
            }
            t0 = t1;
        }
    }
}

impl RowBackend for RustRowBackend<'_> {
    fn len(&self) -> usize {
        self.points.rows()
    }

    fn fill_diag(&self, out: &mut [f64]) {
        match self.kind {
            // exp(-γ·0) = 1
            KernelKind::Rbf { .. } => out.iter_mut().for_each(|o| *o = 1.0),
            KernelKind::Linear => out.copy_from_slice(&self.norms),
            KernelKind::Poly {
                gamma,
                coef0,
                degree,
            } => {
                for (o, &n) in out.iter_mut().zip(&self.norms) {
                    *o = (gamma * n + coef0).powi(degree as i32);
                }
            }
        }
    }

    /// Tiled + parallel batch path: requested rows are split into
    /// [`QUERY_BLOCK`]-sized blocks, blocks are distributed over the
    /// [`crate::util::pool`] workers, and each block runs the tiled
    /// micro-kernel over its disjoint window of `out`.
    fn fill_rows_batch(&self, idxs: &[usize], out: &mut [f32]) {
        let n = self.points.rows();
        assert_eq!(
            out.len(),
            idxs.len() * n,
            "fill_rows_batch: out holds {} values, need {} rows x {} points",
            out.len(),
            idxs.len(),
            n
        );
        if idxs.is_empty() {
            return;
        }
        let nblocks = idxs.len().div_ceil(QUERY_BLOCK);
        if pool::num_threads() <= 1 || nblocks <= 1 {
            self.fill_rows_block(idxs, out);
            return;
        }
        // Each block writes the disjoint `QUERY_BLOCK * n`-sized window
        // of `out` its rows map to (`pool::parallel_fill_chunks` owns
        // the safety argument).
        pool::parallel_fill_chunks(out, QUERY_BLOCK * n, 1, |b, window| {
            let k0 = b * QUERY_BLOCK;
            let k1 = (k0 + QUERY_BLOCK).min(idxs.len());
            self.fill_rows_block(&idxs[k0..k1], window);
        });
    }

    fn fill_row(&self, i: usize, out: &mut [f32]) {
        let a = self.points.row(i);
        match self.kind {
            KernelKind::Rbf { gamma } => {
                if let Some(c) = self.dists {
                    for (o, &d2) in out.iter_mut().zip(c.row(i)) {
                        *o = (-gamma * d2 as f64).exp() as f32;
                    }
                    return;
                }
                let na = self.norms[i];
                for j in 0..self.points.rows() {
                    let d2 = (na + self.norms[j] - 2.0 * dot(a, self.points.row(j)) as f64)
                        .max(0.0);
                    out[j] = (-gamma * d2).exp() as f32;
                }
            }
            KernelKind::Linear => {
                for j in 0..self.points.rows() {
                    out[j] = dot(a, self.points.row(j));
                }
            }
            KernelKind::Poly {
                gamma,
                coef0,
                degree,
            } => {
                for j in 0..self.points.rows() {
                    out[j] = ((gamma * dot(a, self.points.row(j)) as f64 + coef0)
                        .powi(degree as i32)) as f32;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rbf_basics() {
        let k = RbfKernel { gamma: 0.5 };
        let a = [0.0f32, 0.0];
        let b = [0.0f32, 2.0];
        assert!((k.eval(&a, &a) - 1.0).abs() < 1e-12);
        assert!((k.eval(&a, &b) - (-2.0f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn rbf_norm_fast_path_matches_direct() {
        let k = RbfKernel { gamma: 0.3 };
        let a: Vec<f32> = (0..9).map(|i| i as f32 * 0.25).collect();
        let b: Vec<f32> = (0..9).map(|i| (9 - i) as f32 * 0.5).collect();
        let na = a.iter().map(|&x| (x as f64).powi(2)).sum();
        let nb = b.iter().map(|&x| (x as f64).powi(2)).sum();
        let direct = k.eval(&a, &b);
        let fast = k.eval_with_norms(&a, &b, na, nb);
        assert!((direct - fast).abs() < 1e-9);
    }

    #[test]
    fn linear_and_poly() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0];
        assert_eq!(LinearKernel.eval(&a, &b), 11.0);
        let p = PolyKernel {
            gamma: 1.0,
            coef0: 1.0,
            degree: 2,
        };
        assert_eq!(p.eval(&a, &b), 144.0);
    }

    #[test]
    fn rust_backend_rows_match_pointwise_eval() {
        let m = Matrix::from_vec(4, 2, vec![0., 0., 1., 0., 0., 1., 2., 2.]).unwrap();
        let kind = KernelKind::Rbf { gamma: 0.7 };
        let backend = RustRowBackend::new(&m, kind);
        let k = kind.build();
        let mut row = vec![0.0f32; 4];
        for i in 0..4 {
            backend.fill_row(i, &mut row);
            for j in 0..4 {
                let want = k.eval(m.row(i), m.row(j)) as f32;
                assert!((row[j] - want).abs() < 1e-6, "K[{i}][{j}]");
            }
        }
    }

    fn random_points(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = crate::util::rng::Pcg64::seed_from(seed);
        let mut m = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                use crate::util::rng::Rng;
                m.set(i, j, rng.normal() as f32);
            }
        }
        m
    }

    #[test]
    fn tiled_row_matches_scalar_row_across_tile_boundaries() {
        for kind in [
            KernelKind::Rbf { gamma: 0.4 },
            KernelKind::Linear,
            KernelKind::Poly {
                gamma: 0.5,
                coef0: 1.0,
                degree: 3,
            },
        ] {
            for n in [1usize, KERNEL_TILE - 1, KERNEL_TILE, KERNEL_TILE + 1] {
                let m = random_points(n, 7, 11 + n as u64);
                let backend = RustRowBackend::new(&m, kind);
                let mut scalar = vec![0.0f32; n];
                let mut tiled = vec![0.0f32; n];
                for i in [0usize, n / 2, n - 1] {
                    backend.fill_row(i, &mut scalar);
                    backend.fill_row_tiled(i, &mut tiled);
                    for j in 0..n {
                        assert!(
                            (scalar[j] - tiled[j]).abs() < 1e-6,
                            "{kind:?} n={n} K[{i}][{j}]: {} vs {}",
                            scalar[j],
                            tiled[j]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn distance_cached_rows_match_direct_rows() {
        let n = KERNEL_TILE + 37;
        let m = random_points(n, 6, 77);
        let kind = KernelKind::Rbf { gamma: 0.6 };
        let cache = crate::svm::dist::DistanceCache::new(&m);
        let direct = RustRowBackend::new(&m, kind);
        let cached = RustRowBackend::with_distances(&m, kind, &cache);
        let mut a = vec![0.0f32; n];
        let mut b = vec![0.0f32; n];
        for i in [0usize, n / 3, n - 1] {
            direct.fill_row(i, &mut a);
            cached.fill_row(i, &mut b);
            for j in 0..n {
                assert!(
                    (a[j] - b[j]).abs() < 1e-5,
                    "K[{i}][{j}]: direct {} vs cached {}",
                    a[j],
                    b[j]
                );
            }
            // The tiled batch path goes through the same cache pass.
            cached.fill_rows_batch(&[i], &mut b);
            direct.fill_row_tiled(i, &mut a);
            assert_eq!(a, b, "cached tile pass must equal tiled pass 1 output");
        }
    }

    #[test]
    fn batch_rows_match_scalar_rows() {
        let n = 2 * KERNEL_TILE + 3;
        let m = random_points(n, 9, 23);
        let backend = RustRowBackend::new(&m, KernelKind::Rbf { gamma: 0.2 });
        let idxs: Vec<usize> = (0..n).step_by(17).collect();
        let mut batch = vec![0.0f32; idxs.len() * n];
        backend.fill_rows_batch(&idxs, &mut batch);
        let mut want = vec![0.0f32; n];
        for (k, &i) in idxs.iter().enumerate() {
            backend.fill_row(i, &mut want);
            assert_eq!(&batch[k * n..(k + 1) * n], &want[..], "row {i}");
        }
    }

    #[test]
    fn default_trait_batch_matches_override() {
        // A backend that does NOT override fill_rows_batch must agree with
        // the tiled override through the trait default.
        struct Wrap<'a>(&'a RustRowBackend<'a>);
        impl RowBackend for Wrap<'_> {
            fn len(&self) -> usize {
                self.0.len()
            }
            fn fill_row(&self, i: usize, out: &mut [f32]) {
                self.0.fill_row(i, out);
            }
        }
        let m = random_points(100, 5, 31);
        let backend = RustRowBackend::new(&m, KernelKind::Linear);
        let wrap = Wrap(&backend);
        let idxs = [3usize, 0, 99, 41];
        let mut a = vec![0.0f32; idxs.len() * 100];
        let mut b = vec![0.0f32; idxs.len() * 100];
        backend.fill_rows_batch(&idxs, &mut a);
        wrap.fill_rows_batch(&idxs, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "fill_rows_batch")]
    fn batch_rejects_wrong_out_length() {
        let m = random_points(8, 3, 41);
        let backend = RustRowBackend::new(&m, KernelKind::Linear);
        let mut out = vec![0.0f32; 7]; // needs 2*8
        backend.fill_rows_batch(&[0, 1], &mut out);
    }

    #[test]
    fn kernel_kind_gamma_accessor() {
        assert_eq!(KernelKind::Rbf { gamma: 2.0 }.gamma(), Some(2.0));
        assert_eq!(KernelKind::Linear.gamma(), None);
    }
}
