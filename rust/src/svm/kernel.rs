//! Kernel functions and batched kernel-row evaluation.
//!
//! All paper experiments use the Gaussian kernel
//! `K(x,y) = exp(-γ‖x−y‖²)`; linear and polynomial kernels are provided
//! for completeness (the paper's "omitted observations" discuss LibLINEAR
//! as a refinement alternative on easy data).
//!
//! Kernel *rows* are the hot path of SMO: `K(x_i, ·)` against the whole
//! training set. [`RowBackend`] abstracts who computes them — the portable
//! rust loops below, or the AOT Pallas/XLA artifact through
//! [`crate::runtime::rbf`] (L1/L2 of the three-layer stack).

use crate::data::matrix::{dot, sqdist, Matrix};

/// Kernel function over feature vectors.
pub trait Kernel: Send + Sync {
    /// K(a, b).
    fn eval(&self, a: &[f32], b: &[f32]) -> f64;

    /// K(x_i, x_j) given precomputed squared norms (RBF fast path uses
    /// `‖a‖² + ‖b‖² − 2a·b`; others ignore the norms).
    fn eval_with_norms(&self, a: &[f32], b: &[f32], _na: f64, _nb: f64) -> f64 {
        self.eval(a, b)
    }

    /// Human-readable parameterization (model files, logs).
    fn describe(&self) -> String;
}

/// Enumerated kernel configuration (serializable into model files).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KernelKind {
    /// exp(-γ‖x−y‖²)
    Rbf {
        /// Bandwidth γ.
        gamma: f64,
    },
    /// x·y
    Linear,
    /// (γ x·y + c)^d
    Poly {
        /// Scale γ.
        gamma: f64,
        /// Offset c.
        coef0: f64,
        /// Degree d.
        degree: u32,
    },
}

impl KernelKind {
    /// Instantiate the kernel object.
    pub fn build(&self) -> Box<dyn Kernel> {
        match *self {
            KernelKind::Rbf { gamma } => Box::new(RbfKernel { gamma }),
            KernelKind::Linear => Box::new(LinearKernel),
            KernelKind::Poly {
                gamma,
                coef0,
                degree,
            } => Box::new(PolyKernel {
                gamma,
                coef0,
                degree,
            }),
        }
    }

    /// The γ parameter if the kernel has one.
    pub fn gamma(&self) -> Option<f64> {
        match *self {
            KernelKind::Rbf { gamma } | KernelKind::Poly { gamma, .. } => Some(gamma),
            KernelKind::Linear => None,
        }
    }
}

/// Gaussian kernel.
#[derive(Clone, Copy, Debug)]
pub struct RbfKernel {
    /// Bandwidth γ.
    pub gamma: f64,
}

impl Kernel for RbfKernel {
    #[inline]
    fn eval(&self, a: &[f32], b: &[f32]) -> f64 {
        (-self.gamma * sqdist(a, b)).exp()
    }

    #[inline]
    fn eval_with_norms(&self, a: &[f32], b: &[f32], na: f64, nb: f64) -> f64 {
        let d2 = (na + nb - 2.0 * dot(a, b) as f64).max(0.0);
        (-self.gamma * d2).exp()
    }

    fn describe(&self) -> String {
        format!("rbf gamma={}", self.gamma)
    }
}

/// Linear kernel.
#[derive(Clone, Copy, Debug)]
pub struct LinearKernel;

impl Kernel for LinearKernel {
    #[inline]
    fn eval(&self, a: &[f32], b: &[f32]) -> f64 {
        dot(a, b) as f64
    }

    fn describe(&self) -> String {
        "linear".to_string()
    }
}

/// Polynomial kernel.
#[derive(Clone, Copy, Debug)]
pub struct PolyKernel {
    /// Scale γ.
    pub gamma: f64,
    /// Offset c.
    pub coef0: f64,
    /// Degree d.
    pub degree: u32,
}

impl Kernel for PolyKernel {
    #[inline]
    fn eval(&self, a: &[f32], b: &[f32]) -> f64 {
        (self.gamma * dot(a, b) as f64 + self.coef0).powi(self.degree as i32)
    }

    fn describe(&self) -> String {
        format!(
            "poly gamma={} coef0={} degree={}",
            self.gamma, self.coef0, self.degree
        )
    }
}

/// Batched kernel-row provider: fills `K(x_i, ·)` for the whole set.
pub trait RowBackend: Send + Sync {
    /// Number of data points.
    fn len(&self) -> usize;
    /// True if empty.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Compute the full kernel row of point `i` into `out` (length =
    /// `len()`), `out[j] = K(x_i, x_j)` as f32 (LibSVM precision).
    fn fill_row(&self, i: usize, out: &mut [f32]);

    /// Kernel diagonal K(x_i, x_i) for all i. Default falls back to full
    /// rows (O(n²·d)); backends override with the O(n·d) direct form —
    /// SMO needs the diagonal at startup and the fallback dominates
    /// startup cost on large sets.
    fn fill_diag(&self, out: &mut [f64]) {
        let mut row = vec![0.0f32; self.len()];
        for i in 0..self.len() {
            self.fill_row(i, &mut row);
            out[i] = row[i] as f64;
        }
    }
}

/// Pure-rust backend with precomputed squared norms (the default; also the
/// reference the PJRT backend is validated against).
pub struct RustRowBackend<'a> {
    points: &'a Matrix,
    kind: KernelKind,
    norms: Vec<f64>,
}

impl<'a> RustRowBackend<'a> {
    /// Precompute norms and wrap the points.
    pub fn new(points: &'a Matrix, kind: KernelKind) -> Self {
        RustRowBackend {
            points,
            kind,
            norms: points.row_sqnorms(),
        }
    }
}

impl RowBackend for RustRowBackend<'_> {
    fn len(&self) -> usize {
        self.points.rows()
    }

    fn fill_diag(&self, out: &mut [f64]) {
        match self.kind {
            // exp(-γ·0) = 1
            KernelKind::Rbf { .. } => out.iter_mut().for_each(|o| *o = 1.0),
            KernelKind::Linear => out.copy_from_slice(&self.norms),
            KernelKind::Poly {
                gamma,
                coef0,
                degree,
            } => {
                for (o, &n) in out.iter_mut().zip(&self.norms) {
                    *o = (gamma * n + coef0).powi(degree as i32);
                }
            }
        }
    }

    fn fill_row(&self, i: usize, out: &mut [f32]) {
        let a = self.points.row(i);
        match self.kind {
            KernelKind::Rbf { gamma } => {
                let na = self.norms[i];
                for j in 0..self.points.rows() {
                    let d2 = (na + self.norms[j] - 2.0 * dot(a, self.points.row(j)) as f64)
                        .max(0.0);
                    out[j] = (-gamma * d2).exp() as f32;
                }
            }
            KernelKind::Linear => {
                for j in 0..self.points.rows() {
                    out[j] = dot(a, self.points.row(j));
                }
            }
            KernelKind::Poly {
                gamma,
                coef0,
                degree,
            } => {
                for j in 0..self.points.rows() {
                    out[j] = ((gamma * dot(a, self.points.row(j)) as f64 + coef0)
                        .powi(degree as i32)) as f32;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rbf_basics() {
        let k = RbfKernel { gamma: 0.5 };
        let a = [0.0f32, 0.0];
        let b = [0.0f32, 2.0];
        assert!((k.eval(&a, &a) - 1.0).abs() < 1e-12);
        assert!((k.eval(&a, &b) - (-2.0f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn rbf_norm_fast_path_matches_direct() {
        let k = RbfKernel { gamma: 0.3 };
        let a: Vec<f32> = (0..9).map(|i| i as f32 * 0.25).collect();
        let b: Vec<f32> = (0..9).map(|i| (9 - i) as f32 * 0.5).collect();
        let na = a.iter().map(|&x| (x as f64).powi(2)).sum();
        let nb = b.iter().map(|&x| (x as f64).powi(2)).sum();
        let direct = k.eval(&a, &b);
        let fast = k.eval_with_norms(&a, &b, na, nb);
        assert!((direct - fast).abs() < 1e-9);
    }

    #[test]
    fn linear_and_poly() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0];
        assert_eq!(LinearKernel.eval(&a, &b), 11.0);
        let p = PolyKernel {
            gamma: 1.0,
            coef0: 1.0,
            degree: 2,
        };
        assert_eq!(p.eval(&a, &b), 144.0);
    }

    #[test]
    fn rust_backend_rows_match_pointwise_eval() {
        let m = Matrix::from_vec(4, 2, vec![0., 0., 1., 0., 0., 1., 2., 2.]).unwrap();
        let kind = KernelKind::Rbf { gamma: 0.7 };
        let backend = RustRowBackend::new(&m, kind);
        let k = kind.build();
        let mut row = vec![0.0f32; 4];
        for i in 0..4 {
            backend.fill_row(i, &mut row);
            for j in 0..4 {
                let want = k.eval(m.row(i), m.row(j)) as f32;
                assert!((row[j] - want).abs() < 1e-6, "K[{i}][{j}]");
            }
        }
    }

    #[test]
    fn kernel_kind_gamma_accessor() {
        assert_eq!(KernelKind::Rbf { gamma: 2.0 }.gamma(), Some(2.0));
        assert_eq!(KernelKind::Linear.gamma(), None);
    }
}
