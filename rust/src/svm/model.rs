//! Trained SVM model: support vectors, dual coefficients, bias, kernel —
//! plus prediction, decision values, and a plain-text serialization
//! (the vendor set has no serde; the format is a simple line protocol
//! compatible in spirit with LibSVM model files).

use crate::data::matrix::Matrix;
use crate::error::{Error, Result};
use crate::svm::kernel::KernelKind;
use crate::svm::smo::SvmParams;
use std::io::{BufWriter, Write};
use std::path::Path;

/// A trained (weighted) SVM.
#[derive(Clone, Debug)]
pub struct SvmModel {
    /// Support vectors (rows).
    pub sv: Matrix,
    /// Coefficients y_i·α_i per support vector.
    pub sv_coef: Vec<f64>,
    /// Bias ρ: decision(x) = Σ coef_i·K(sv_i, x) − ρ.
    pub rho: f64,
    /// Kernel used at training time.
    pub kernel: KernelKind,
    /// Indices of the support vectors in the training set the model was
    /// fit on (needed by the multilevel uncoarsening).
    pub sv_indices: Vec<usize>,
    /// Labels of the support vectors.
    pub sv_labels: Vec<i8>,
}

impl SvmModel {
    /// Package a solver solution: keep points with α > threshold.
    pub fn from_solution(
        points: &Matrix,
        labels: &[i8],
        alpha: &[f64],
        rho: f64,
        params: &SvmParams,
    ) -> SvmModel {
        let thresh = 1e-9;
        let sv_indices: Vec<usize> = (0..alpha.len()).filter(|&i| alpha[i] > thresh).collect();
        let sv = points.select_rows(&sv_indices);
        let sv_coef = sv_indices
            .iter()
            .map(|&i| alpha[i] * labels[i] as f64)
            .collect();
        let sv_labels = sv_indices.iter().map(|&i| labels[i]).collect();
        SvmModel {
            sv,
            sv_coef,
            rho,
            kernel: params.kernel,
            sv_indices,
            sv_labels,
        }
    }

    /// Number of support vectors.
    pub fn n_sv(&self) -> usize {
        self.sv_coef.len()
    }

    /// Decision value f(x) = Σ coef_i K(sv_i, x) − ρ.
    pub fn decision(&self, x: &[f32]) -> f64 {
        let k = self.kernel.build();
        let mut s = -self.rho;
        for i in 0..self.n_sv() {
            s += self.sv_coef[i] * k.eval(self.sv.row(i), x);
        }
        s
    }

    /// Predicted label in {-1, +1} (ties → −1, the majority class).
    pub fn predict_label(&self, x: &[f32]) -> i8 {
        if self.decision(x) > 0.0 {
            1
        } else {
            -1
        }
    }

    /// Batch decision values (pure-rust path; the PJRT-artifact path lives
    /// in [`crate::runtime::rbf`] and is validated against this). The
    /// kernel object is built once and queries are distributed over the
    /// [`crate::util::pool`] workers.
    pub fn decision_batch(&self, xs: &Matrix) -> Vec<f64> {
        let k = self.kernel.build();
        let k = k.as_ref();
        crate::util::pool::parallel_map(xs.rows(), 8, |i| {
            let x = xs.row(i);
            let mut s = -self.rho;
            for v in 0..self.n_sv() {
                s += self.sv_coef[v] * k.eval(self.sv.row(v), x);
            }
            s
        })
    }

    /// Batch labels.
    pub fn predict_batch(&self, xs: &Matrix) -> Vec<i8> {
        self.decision_batch(xs)
            .into_iter()
            .map(|d| if d > 0.0 { 1 } else { -1 })
            .collect()
    }

    /// Save as plain text.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let f = std::fs::File::create(path)?;
        let mut w = BufWriter::new(f);
        self.write_text(&mut w)?;
        w.flush()?;
        Ok(())
    }

    /// Write the line protocol into any writer (also embedded as a
    /// section of the [`crate::serve::registry`] multi-model format).
    pub fn write_text<W: Write>(&self, w: &mut W) -> Result<()> {
        match self.kernel {
            KernelKind::Rbf { gamma } => writeln!(w, "kernel rbf {gamma}")?,
            KernelKind::Linear => writeln!(w, "kernel linear")?,
            KernelKind::Poly {
                gamma,
                coef0,
                degree,
            } => writeln!(w, "kernel poly {gamma} {coef0} {degree}")?,
        }
        writeln!(w, "rho {}", self.rho)?;
        writeln!(w, "nsv {} dim {}", self.n_sv(), self.sv.cols())?;
        for i in 0..self.n_sv() {
            write!(w, "{} {}", self.sv_coef[i], self.sv_labels[i])?;
            for &v in self.sv.row(i) {
                write!(w, " {v}")?;
            }
            writeln!(w)?;
        }
        Ok(())
    }

    /// Load from the plain-text format written by [`SvmModel::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<SvmModel> {
        let text = std::fs::read_to_string(path)?;
        SvmModel::parse_lines(&mut text.lines())
    }

    /// Parse the line protocol from an iterator of lines, consuming
    /// exactly the lines the model occupies (the registry reads several
    /// models out of one file this way).
    pub fn parse_lines<'b>(lines: &mut impl Iterator<Item = &'b str>) -> Result<SvmModel> {
        let mut next_line = |what: &str| -> Result<&'b str> {
            lines
                .next()
                .ok_or_else(|| Error::invalid(format!("model file truncated at {what}")))
        };
        let kline = next_line("kernel")?;
        let ktok: Vec<&str> = kline.split_whitespace().collect();
        let kernel = match ktok.as_slice() {
            ["kernel", "rbf", g] => KernelKind::Rbf {
                gamma: g.parse().map_err(|_| Error::invalid("bad gamma"))?,
            },
            ["kernel", "linear"] => KernelKind::Linear,
            ["kernel", "poly", g, c, d] => KernelKind::Poly {
                gamma: g.parse().map_err(|_| Error::invalid("bad gamma"))?,
                coef0: c.parse().map_err(|_| Error::invalid("bad coef0"))?,
                degree: d.parse().map_err(|_| Error::invalid("bad degree"))?,
            },
            _ => return Err(Error::invalid(format!("bad kernel line '{kline}'"))),
        };
        let rline = next_line("rho")?;
        let rho: f64 = rline
            .strip_prefix("rho ")
            .ok_or_else(|| Error::invalid("missing rho"))?
            .parse()
            .map_err(|_| Error::invalid("bad rho"))?;
        let nline = next_line("nsv")?;
        let ntok: Vec<&str> = nline.split_whitespace().collect();
        let (nsv, dim) = match ntok.as_slice() {
            ["nsv", n, "dim", d] => (
                n.parse::<usize>().map_err(|_| Error::invalid("bad nsv"))?,
                d.parse::<usize>().map_err(|_| Error::invalid("bad dim"))?,
            ),
            _ => return Err(Error::invalid("bad nsv line")),
        };
        let mut sv = Matrix::zeros(nsv, dim);
        let mut sv_coef = Vec::with_capacity(nsv);
        let mut sv_labels = Vec::with_capacity(nsv);
        for i in 0..nsv {
            let line = next_line("sv")?;
            let mut it = line.split_whitespace();
            let coef: f64 = it
                .next()
                .ok_or_else(|| Error::invalid("sv line empty"))?
                .parse()
                .map_err(|_| Error::invalid("bad coef"))?;
            let lab: i8 = it
                .next()
                .ok_or_else(|| Error::invalid("sv line missing label"))?
                .parse()
                .map_err(|_| Error::invalid("bad label"))?;
            sv_coef.push(coef);
            sv_labels.push(lab);
            let row = sv.row_mut(i);
            for (j, r) in row.iter_mut().enumerate() {
                *r = it
                    .next()
                    .ok_or_else(|| Error::invalid(format!("sv {i} missing feature {j}")))?
                    .parse()
                    .map_err(|_| Error::invalid("bad feature"))?;
            }
        }
        Ok(SvmModel {
            sv,
            sv_coef,
            rho,
            kernel,
            sv_indices: Vec::new(),
            sv_labels,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::two_gaussians;
    use crate::svm::smo::{train, SvmParams};
    use crate::util::rng::Pcg64;

    fn fixture_model() -> (SvmModel, crate::data::dataset::Dataset) {
        let mut rng = Pcg64::seed_from(51);
        let ds = two_gaussians(60, 60, 3, 4.0, &mut rng);
        let p = SvmParams {
            kernel: KernelKind::Rbf { gamma: 0.4 },
            ..Default::default()
        };
        (train(&ds.points, &ds.labels, &p).unwrap(), ds)
    }

    #[test]
    fn sv_set_is_subset_of_training() {
        let (m, ds) = fixture_model();
        assert!(m.n_sv() > 0);
        assert!(m.n_sv() < ds.len(), "not all points should be SVs");
        for (r, &i) in m.sv_indices.iter().enumerate() {
            assert_eq!(m.sv.row(r), ds.points.row(i));
        }
    }

    #[test]
    fn decision_batch_matches_single() {
        let (m, ds) = fixture_model();
        let batch = m.decision_batch(&ds.points);
        for i in (0..ds.len()).step_by(13) {
            assert!((batch[i] - m.decision(ds.points.row(i))).abs() < 1e-12);
        }
    }

    #[test]
    fn save_load_roundtrip_preserves_decisions() {
        let (m, ds) = fixture_model();
        let dir = std::env::temp_dir().join("mlsvm_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.txt");
        m.save(&path).unwrap();
        let back = SvmModel::load(&path).unwrap();
        for i in (0..ds.len()).step_by(7) {
            let a = m.decision(ds.points.row(i));
            let b = back.decision(ds.points.row(i));
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("mlsvm_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.txt");
        std::fs::write(&path, "not a model\n").unwrap();
        assert!(SvmModel::load(&path).is_err());
    }
}
