//! The (weighted) support vector machine substrate — a from-scratch
//! LibSVM-3.20 equivalent:
//!
//! * [`kernel`] — kernel functions (Gaussian/RBF as in the paper, plus
//!   linear and polynomial) and a pluggable backend for batched kernel
//!   row evaluation (pure rust, or the PJRT AOT artifact via
//!   [`crate::runtime`]);
//! * [`cache`] — an LRU kernel-row cache (LibSVM's `Cache`);
//! * [`dist`] — a shared pairwise squared-distance cache that model
//!   selection layers under the RBF kernel (γ trials reuse the geometry);
//! * [`smo`] — C-SVC dual SMO solver with second-order working-set
//!   selection (WSS2, Fan–Chen–Lin 2005), shrinking, and per-class
//!   penalties C⁺ / C⁻ (the WSVM of Eq. 2);
//! * [`model`] — the trained model (support vectors, coefficients, bias),
//!   decision function and prediction.

pub mod cache;
pub mod dist;
pub mod kernel;
pub mod model;
pub mod smo;

pub use dist::DistanceCache;
pub use kernel::{Kernel, KernelKind, LinearKernel, RbfKernel, RowBackend, KERNEL_TILE};
pub use model::SvmModel;
pub use smo::{train, train_weighted, train_weighted_warm, SvmParams, TrainStats};
