//! Shared pairwise squared-distance cache for RBF model selection.
//!
//! Every RBF candidate evaluated on one CV fold needs the same Gram
//! *geometry*: the pairwise squared Euclidean distances of the fold's
//! training points. Only the bandwidth γ differs between candidates, and
//! γ enters through the cheap `exp(-γ·d²)` pass. The UD search therefore
//! computes `d²` once per fold with [`DistanceCache::new`] and layers it
//! under [`KernelKind::Rbf`] via
//! [`RustRowBackend::with_distances`](crate::svm::kernel::RustRowBackend::with_distances),
//! turning every subsequent `(C, γ, ratio)` trial's kernel-row fill into a
//! transcendental-only pass.
//!
//! Entries are stored exactly as the tiled kernel micro-kernel's pass 1
//! produces them (`f32` of the norm-identity `‖a‖² + ‖b‖² − 2a·b`, clamped
//! at 0), so cache-backed rows match the tiled direct path bit-for-bit.
//! The fill parallelizes over rows through [`crate::util::pool`]; each row
//! is written by exactly one worker, so the result is identical at any
//! thread count.

use crate::data::matrix::{dot, Matrix};
use crate::util::pool;

/// Dense row-major `n × n` matrix of pairwise squared distances.
pub struct DistanceCache {
    n: usize,
    d2: Vec<f32>,
}

/// Rows per parallel task when filling the cache (rows are O(n·d) each, so
/// small chunks balance fine).
const FILL_CHUNK: usize = 8;

impl DistanceCache {
    /// Largest point count the cache will materialize (`MAX_POINTS² × 4`
    /// bytes ≈ 16 MiB). Model selection runs on level training sets
    /// bounded by `Q_dt` (~1200 in the paper), far below this; callers on
    /// bigger sets should check [`DistanceCache::fits`] and fall back to
    /// direct evaluation.
    pub const MAX_POINTS: usize = 2048;

    /// Whether a set of `n` points is small enough to cache.
    pub fn fits(n: usize) -> bool {
        n <= Self::MAX_POINTS
    }

    /// Compute all pairwise squared distances of `points` (parallel over
    /// rows, deterministic at any thread count).
    pub fn new(points: &Matrix) -> DistanceCache {
        let n = points.rows();
        let norms = points.row_sqnorms();
        let mut d2 = vec![0.0f32; n * n];
        // Disjoint per-row windows: row i is written only by the task
        // that drew index i (`pool::parallel_fill_chunks` owns the
        // safety argument).
        pool::parallel_fill_chunks(&mut d2, n, FILL_CHUNK, |i, row| {
            let a = points.row(i);
            let na = norms[i];
            for (j, out) in row.iter_mut().enumerate() {
                let v = (na + norms[j] - 2.0 * dot(a, points.row(j)) as f64).max(0.0);
                *out = v as f32;
            }
        });
        DistanceCache { n, d2 }
    }

    /// Number of points.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when built over zero points.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Squared distances of point `i` to every point (length `len()`).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.d2[i * self.n..(i + 1) * self.n]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::sqdist;
    use crate::util::rng::{Pcg64, Rng};

    fn random_points(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seed_from(seed);
        let mut m = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.set(i, j, rng.normal() as f32);
            }
        }
        m
    }

    #[test]
    fn entries_match_direct_sqdist() {
        let m = random_points(60, 7, 3);
        let c = DistanceCache::new(&m);
        assert_eq!(c.len(), 60);
        for i in 0..60 {
            let row = c.row(i);
            for j in 0..60 {
                let want = sqdist(m.row(i), m.row(j));
                assert!(
                    (row[j] as f64 - want).abs() <= 1e-4 * want.max(1.0),
                    "d2[{i}][{j}] = {} vs {want}",
                    row[j]
                );
            }
            assert!(row[i].abs() < 1e-5, "diagonal must be ~0, got {}", row[i]);
        }
    }

    #[test]
    fn fill_is_thread_count_invariant() {
        let _guard = pool::TEST_OVERRIDE_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let m = random_points(97, 5, 9);
        pool::set_num_threads(1);
        let serial = DistanceCache::new(&m);
        pool::set_num_threads(4);
        let parallel = DistanceCache::new(&m);
        pool::set_num_threads(0);
        assert_eq!(serial.d2, parallel.d2, "cache fill must be bit-identical");
    }

    #[test]
    fn fits_respects_cap() {
        assert!(DistanceCache::fits(DistanceCache::MAX_POINTS));
        assert!(!DistanceCache::fits(DistanceCache::MAX_POINTS + 1));
    }
}
