//! LRU kernel-row cache — the LibSVM `Cache` equivalent.
//!
//! SMO touches the same kernel rows repeatedly (active working-set
//! variables). The cache bounds memory to `capacity_bytes` and evicts the
//! least-recently-used full row. Rows are f32 (as in LibSVM); misses are
//! delegated to the [`RowBackend`].

use crate::svm::kernel::RowBackend;
use std::collections::HashMap;

/// LRU cache of kernel rows.
pub struct KernelCache<'a> {
    backend: &'a dyn RowBackend,
    n: usize,
    capacity_rows: usize,
    rows: HashMap<usize, Box<[f32]>>,
    // LRU order: front = oldest. Small (≤ capacity_rows) so Vec is fine.
    order: Vec<usize>,
    hits: u64,
    misses: u64,
}

impl<'a> KernelCache<'a> {
    /// Cache with the given byte budget (min 2 rows).
    pub fn new(backend: &'a dyn RowBackend, capacity_bytes: usize) -> Self {
        let n = backend.len();
        let row_bytes = (n * std::mem::size_of::<f32>()).max(1);
        let capacity_rows = (capacity_bytes / row_bytes).max(2);
        KernelCache {
            backend,
            n,
            capacity_rows,
            rows: HashMap::new(),
            order: Vec::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// Number of points (row length).
    pub fn n(&self) -> usize {
        self.n
    }

    /// (hits, misses) counters — perf instrumentation.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Get row `i`, computing and caching it if absent.
    pub fn row(&mut self, i: usize) -> &[f32] {
        if self.rows.contains_key(&i) {
            self.hits += 1;
            // refresh LRU position
            if let Some(pos) = self.order.iter().position(|&x| x == i) {
                self.order.remove(pos);
            }
            self.order.push(i);
        } else {
            self.misses += 1;
            if self.rows.len() >= self.capacity_rows {
                let evict = self.order.remove(0);
                self.rows.remove(&evict);
            }
            let mut buf = vec![0.0f32; self.n].into_boxed_slice();
            self.backend.fill_row(i, &mut buf);
            self.rows.insert(i, buf);
            self.order.push(i);
        }
        self.rows.get(&i).unwrap()
    }

    /// Get rows `i` and `j` simultaneously (the SMO update needs both).
    pub fn row_pair(&mut self, i: usize, j: usize) -> (&[f32], &[f32]) {
        assert_ne!(i, j);
        // Ensure both are resident (order matters so neither evicts the other:
        // capacity ≥ 2 guarantees the second fetch cannot evict the first
        // because the first was just refreshed... unless capacity is 2 and
        // both were absent; fetching j after i evicts the oldest, which is
        // not i since i was appended last).
        self.row(i);
        self.row(j);
        let ri = self.rows.get(&i).unwrap().as_ref() as *const [f32];
        let rj = self.rows.get(&j).unwrap().as_ref();
        // SAFETY: distinct keys -> distinct boxes; no mutation until the
        // returned borrows end (we hold &mut self).
        (unsafe { &*ri }, rj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;
    use crate::svm::kernel::{KernelKind, RustRowBackend};

    fn backend_fixture(n: usize) -> Matrix {
        let mut data = Vec::with_capacity(n * 2);
        for i in 0..n {
            data.push(i as f32);
            data.push((i % 3) as f32);
        }
        Matrix::from_vec(n, 2, data).unwrap()
    }

    #[test]
    fn hit_and_miss_counting() {
        let m = backend_fixture(8);
        let b = RustRowBackend::new(&m, KernelKind::Rbf { gamma: 0.1 });
        let mut cache = KernelCache::new(&b, 1 << 20);
        cache.row(0);
        cache.row(0);
        cache.row(1);
        let (h, mi) = cache.stats();
        assert_eq!(h, 1);
        assert_eq!(mi, 2);
    }

    #[test]
    fn eviction_keeps_capacity() {
        let m = backend_fixture(16);
        let b = RustRowBackend::new(&m, KernelKind::Linear);
        // capacity for exactly 2 rows
        let bytes = 2 * 16 * 4;
        let mut cache = KernelCache::new(&b, bytes);
        cache.row(0);
        cache.row(1);
        cache.row(2); // evicts 0
        assert!(cache.rows.len() <= 2);
        let (_, misses0) = cache.stats();
        cache.row(0); // miss again
        let (_, misses1) = cache.stats();
        assert_eq!(misses1, misses0 + 1);
    }

    #[test]
    fn row_pair_returns_both_correctly() {
        let m = backend_fixture(6);
        let b = RustRowBackend::new(&m, KernelKind::Linear);
        let mut cache = KernelCache::new(&b, 2 * 6 * 4);
        let (ri, rj) = cache.row_pair(2, 5);
        let mut want_i = vec![0.0f32; 6];
        let mut want_j = vec![0.0f32; 6];
        b.fill_row(2, &mut want_i);
        b.fill_row(5, &mut want_j);
        assert_eq!(ri, &want_i[..]);
        assert_eq!(rj, &want_j[..]);
    }

    #[test]
    fn values_match_backend_after_heavy_eviction() {
        let m = backend_fixture(10);
        let b = RustRowBackend::new(&m, KernelKind::Rbf { gamma: 0.5 });
        let mut cache = KernelCache::new(&b, 2 * 10 * 4);
        let mut want = vec![0.0f32; 10];
        for pass in 0..3 {
            for i in 0..10 {
                let got = cache.row(i).to_vec();
                b.fill_row(i, &mut want);
                assert_eq!(got, want, "pass {pass} row {i}");
            }
        }
    }
}
