//! O(1) LRU kernel-row cache — the LibSVM `Cache` equivalent.
//!
//! SMO touches the same kernel rows repeatedly (active working-set
//! variables). The cache bounds memory to `capacity_bytes` and evicts the
//! least-recently-used full row. Rows are f32 (as in LibSVM); misses are
//! delegated to the [`RowBackend`].
//!
//! Every operation is O(1) in the number of cached rows: residency is a
//! direct-indexed `key -> slot` table and recency is an intrusive
//! prev/next list threaded through a slab of row slots. Evicted rows hand
//! their buffer to the incoming row instead of reallocating, so a solver
//! at steady state performs no allocation at all. [`KernelCache::row_pair`]
//! pins the first row while the second is fetched, which makes the
//! capacity-2 case correct by construction rather than by argument.
//! [`KernelCache::rows_batch`] groups misses and delegates them to the
//! backend's batched (parallel, tiled) path in capacity-bounded segments.

use crate::svm::kernel::RowBackend;

/// Sentinel for "no slot" in the intrusive list and the index table.
const NIL: u32 = u32::MAX;

/// One slab entry: a cached kernel row plus its intrusive LRU links.
struct Slot {
    /// Row index this slot currently holds.
    key: u32,
    /// Next slot toward the LRU end (NIL at the tail).
    next: u32,
    /// Previous slot toward the MRU end (NIL at the head).
    prev: u32,
    /// Pinned slots are skipped by eviction (held by `row_pair`).
    pinned: bool,
    /// The row values (length = number of points).
    buf: Box<[f32]>,
}

/// O(1) LRU cache of kernel rows.
pub struct KernelCache<'a> {
    backend: &'a dyn RowBackend,
    n: usize,
    capacity_rows: usize,
    /// key -> slot index, NIL when not resident. O(1) lookup without
    /// hashing (keys are dense row indices).
    index: Vec<u32>,
    slots: Vec<Slot>,
    /// Most-recently-used slot (NIL when empty).
    head: u32,
    /// Least-recently-used slot (NIL when empty).
    tail: u32,
    hits: u64,
    misses: u64,
    /// Staging buffer for `rows_batch` misses, recycled between calls.
    scratch: Vec<f32>,
}

impl<'a> KernelCache<'a> {
    /// Cache with the given byte budget (min 2 rows).
    pub fn new(backend: &'a dyn RowBackend, capacity_bytes: usize) -> Self {
        let n = backend.len();
        let row_bytes = (n * std::mem::size_of::<f32>()).max(1);
        let capacity_rows = (capacity_bytes / row_bytes).max(2);
        KernelCache {
            backend,
            n,
            capacity_rows,
            index: vec![NIL; n],
            slots: Vec::new(),
            head: NIL,
            tail: NIL,
            hits: 0,
            misses: 0,
            scratch: Vec::new(),
        }
    }

    /// Number of points (row length).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Maximum number of rows the cache will hold.
    pub fn capacity_rows(&self) -> usize {
        self.capacity_rows
    }

    /// (hits, misses) counters — perf instrumentation.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Resident row keys from least- to most-recently used (test/debug
    /// introspection of the LRU order).
    pub fn lru_keys(&self) -> Vec<usize> {
        let mut keys = Vec::with_capacity(self.slots.len());
        let mut s = self.tail;
        while s != NIL {
            let slot = &self.slots[s as usize];
            keys.push(slot.key as usize);
            s = slot.prev;
        }
        keys
    }

    fn unlink(&mut self, s: u32) {
        let (prev, next) = {
            let slot = &self.slots[s as usize];
            (slot.prev, slot.next)
        };
        if prev != NIL {
            self.slots[prev as usize].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slots[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn push_front(&mut self, s: u32) {
        let old_head = self.head;
        {
            let slot = &mut self.slots[s as usize];
            slot.prev = NIL;
            slot.next = old_head;
        }
        if old_head != NIL {
            self.slots[old_head as usize].prev = s;
        }
        self.head = s;
        if self.tail == NIL {
            self.tail = s;
        }
    }

    /// Move a resident slot to the MRU position.
    fn touch(&mut self, s: u32) {
        if self.head != s {
            self.unlink(s);
            self.push_front(s);
        }
    }

    /// Claim a slot for `key` (grow the slab below capacity, otherwise
    /// recycle the least-recently-used unpinned slot, buffer included) and
    /// link it at the MRU position. The buffer contents are stale — the
    /// caller fills them.
    fn alloc_slot(&mut self, key: usize) -> usize {
        debug_assert_eq!(self.index[key], NIL);
        let s = if self.slots.len() < self.capacity_rows {
            let s = self.slots.len() as u32;
            self.slots.push(Slot {
                key: key as u32,
                next: NIL,
                prev: NIL,
                pinned: false,
                buf: vec![0.0f32; self.n].into_boxed_slice(),
            });
            s
        } else {
            // Walk from the true LRU end past any pinned slots.
            let mut s = self.tail;
            while s != NIL && self.slots[s as usize].pinned {
                s = self.slots[s as usize].prev;
            }
            if s == NIL {
                // Every slot pinned (cannot happen with capacity >= 2 and
                // the single pin of row_pair); grow past capacity rather
                // than deadlock.
                let s = self.slots.len() as u32;
                self.slots.push(Slot {
                    key: key as u32,
                    next: NIL,
                    prev: NIL,
                    pinned: false,
                    buf: vec![0.0f32; self.n].into_boxed_slice(),
                });
                s
            } else {
                self.unlink(s);
                let slot = &mut self.slots[s as usize];
                self.index[slot.key as usize] = NIL;
                slot.key = key as u32;
                s
            }
        };
        self.index[key] = s;
        self.push_front(s);
        s as usize
    }

    /// Get row `i`, computing and caching it if absent. O(1) bookkeeping.
    pub fn row(&mut self, i: usize) -> &[f32] {
        let s = self.index[i];
        if s != NIL {
            self.hits += 1;
            self.touch(s);
            return &self.slots[s as usize].buf;
        }
        self.misses += 1;
        let s = self.alloc_slot(i);
        let backend = self.backend;
        backend.fill_row(i, &mut self.slots[s].buf);
        &self.slots[s].buf
    }

    /// Get rows `i` and `j` simultaneously (the SMO update needs both).
    /// Row `i` is pinned while `j` is fetched, so neither can evict the
    /// other at any capacity.
    pub fn row_pair(&mut self, i: usize, j: usize) -> (&[f32], &[f32]) {
        assert_ne!(i, j);
        self.row(i);
        let si = self.index[i] as usize;
        self.slots[si].pinned = true;
        self.row(j);
        self.slots[si].pinned = false;
        let sj = self.index[j] as usize;
        debug_assert_ne!(si, sj);
        // Disjoint slots -> disjoint borrows via split_at.
        if si < sj {
            let (a, b) = self.slots.split_at(sj);
            (&a[si].buf, &b[0].buf)
        } else {
            let (a, b) = self.slots.split_at(si);
            (&b[0].buf, &a[sj].buf)
        }
    }

    /// Make the given rows resident (up to capacity): hits are refreshed,
    /// misses are grouped and computed by batched backend calls
    /// ([`RowBackend::fill_rows_batch`] — tiled and parallel on the rust
    /// backend) and then inserted. Duplicate indices are counted once.
    /// The staging buffer is bounded by one capacity's worth of rows, so
    /// the cache's byte budget holds; when more rows than the capacity
    /// are requested, later rows win the slots — values are always
    /// correct, residency is best-effort.
    pub fn rows_batch(&mut self, idxs: &[usize]) {
        let mut miss: Vec<usize> = Vec::new();
        for &i in idxs {
            let s = self.index[i];
            if s != NIL {
                self.hits += 1;
                self.touch(s);
            } else {
                miss.push(i);
            }
        }
        miss.sort_unstable();
        miss.dedup();
        if miss.is_empty() {
            return;
        }
        self.misses += miss.len() as u64;
        for seg in miss.chunks(self.capacity_rows) {
            self.scratch.resize(seg.len() * self.n, 0.0);
            self.backend.fill_rows_batch(seg, &mut self.scratch);
            for (k, &i) in seg.iter().enumerate() {
                let s = self.alloc_slot(i);
                self.slots[s]
                    .buf
                    .copy_from_slice(&self.scratch[k * self.n..(k + 1) * self.n]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;
    use crate::svm::kernel::{KernelKind, RustRowBackend};

    fn backend_fixture(n: usize) -> Matrix {
        let mut data = Vec::with_capacity(n * 2);
        for i in 0..n {
            data.push(i as f32);
            data.push((i % 3) as f32);
        }
        Matrix::from_vec(n, 2, data).unwrap()
    }

    #[test]
    fn hit_and_miss_counting() {
        let m = backend_fixture(8);
        let b = RustRowBackend::new(&m, KernelKind::Rbf { gamma: 0.1 });
        let mut cache = KernelCache::new(&b, 1 << 20);
        cache.row(0);
        cache.row(0);
        cache.row(1);
        let (h, mi) = cache.stats();
        assert_eq!(h, 1);
        assert_eq!(mi, 2);
    }

    #[test]
    fn eviction_keeps_capacity_and_true_lru_order() {
        let m = backend_fixture(16);
        let b = RustRowBackend::new(&m, KernelKind::Linear);
        // capacity for exactly 2 rows
        let bytes = 2 * 16 * 4;
        let mut cache = KernelCache::new(&b, bytes);
        cache.row(0);
        cache.row(1);
        cache.row(2); // evicts 0
        assert_eq!(cache.lru_keys(), vec![1, 2]);
        let (_, misses0) = cache.stats();
        cache.row(0); // miss again, evicts 1
        let (_, misses1) = cache.stats();
        assert_eq!(misses1, misses0 + 1);
        assert_eq!(cache.lru_keys(), vec![2, 0]);
    }

    #[test]
    fn row_pair_returns_both_correctly() {
        let m = backend_fixture(6);
        let b = RustRowBackend::new(&m, KernelKind::Linear);
        let mut cache = KernelCache::new(&b, 2 * 6 * 4);
        let (ri, rj) = cache.row_pair(2, 5);
        let mut want_i = vec![0.0f32; 6];
        let mut want_j = vec![0.0f32; 6];
        b.fill_row(2, &mut want_i);
        b.fill_row(5, &mut want_j);
        assert_eq!(ri, &want_i[..]);
        assert_eq!(rj, &want_j[..]);
    }

    #[test]
    fn row_pair_at_capacity_two_never_evicts_its_own_rows() {
        let m = backend_fixture(12);
        let b = RustRowBackend::new(&m, KernelKind::Linear);
        let mut cache = KernelCache::new(&b, 2 * 12 * 4);
        assert_eq!(cache.capacity_rows(), 2);
        // Both rows absent, cache already full with other rows: the pin
        // must protect the first fetch while the second evicts.
        cache.row(0);
        cache.row(1);
        let (ri, rj) = cache.row_pair(7, 9);
        let mut want_i = vec![0.0f32; 12];
        let mut want_j = vec![0.0f32; 12];
        b.fill_row(7, &mut want_i);
        b.fill_row(9, &mut want_j);
        assert_eq!(ri, &want_i[..]);
        assert_eq!(rj, &want_j[..]);
        assert_eq!(cache.lru_keys(), vec![7, 9]);
    }

    #[test]
    fn values_match_backend_after_heavy_eviction() {
        let m = backend_fixture(10);
        let b = RustRowBackend::new(&m, KernelKind::Rbf { gamma: 0.5 });
        let mut cache = KernelCache::new(&b, 2 * 10 * 4);
        let mut want = vec![0.0f32; 10];
        for pass in 0..3 {
            for i in 0..10 {
                let got = cache.row(i).to_vec();
                b.fill_row(i, &mut want);
                assert_eq!(got, want, "pass {pass} row {i}");
            }
        }
    }

    #[test]
    fn rows_batch_groups_misses_and_counts_duplicates_once() {
        let m = backend_fixture(20);
        let b = RustRowBackend::new(&m, KernelKind::Rbf { gamma: 0.3 });
        let mut cache = KernelCache::new(&b, 8 * 20 * 4);
        cache.row(3);
        cache.rows_batch(&[3, 5, 7, 5, 9]);
        let (h, mi) = cache.stats();
        assert_eq!(h, 1, "3 was resident");
        assert_eq!(mi, 1 + 3, "first row(3) plus misses {{5,7,9}}");
        // all requested rows resident with correct values
        let mut want = vec![0.0f32; 20];
        for i in [3usize, 5, 7, 9] {
            b.fill_row(i, &mut want);
            assert_eq!(cache.row(i), &want[..], "row {i}");
        }
        let (h2, mi2) = cache.stats();
        assert_eq!(h2, 1 + 4);
        assert_eq!(mi2, 4);
    }

    #[test]
    fn rows_batch_larger_than_capacity_stays_correct() {
        let m = backend_fixture(10);
        let b = RustRowBackend::new(&m, KernelKind::Linear);
        let mut cache = KernelCache::new(&b, 3 * 10 * 4);
        let all: Vec<usize> = (0..10).collect();
        cache.rows_batch(&all);
        assert_eq!(cache.lru_keys().len(), cache.capacity_rows());
        let mut want = vec![0.0f32; 10];
        for i in 0..10 {
            b.fill_row(i, &mut want);
            assert_eq!(cache.row(i), &want[..], "row {i}");
        }
    }
}
