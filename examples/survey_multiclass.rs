//! The industrial pipeline (Table 2 setting): simulated customer-
//! satisfaction surveys → uni/bi-gram tf-idf → randomized SVD to 100
//! dimensions → one-vs-rest MLWSVM per class through the coordinator's
//! job queue → per-class ACC/κ.
//!
//! ```bash
//! cargo run --release --example survey_multiclass -- [--scale 0.02]
//! ```

use mlsvm::coordinator::report::{fmt_secs, Table};
use mlsvm::coordinator::OneVsRestTrainer;
use mlsvm::data::synth::survey::{self, SurveyConfig};
use mlsvm::prelude::*;
use mlsvm::util::cli::Args;
use mlsvm::util::timer::Timer;

fn main() -> Result<()> {
    let args = Args::new("survey_multiclass", "BMW-style DS1 pipeline")
        .opt("scale", "fraction of DS1 class sizes", Some("0.05"))
        .opt("svd-dim", "SVD output dimensionality", Some("100"))
        .opt("seed", "random seed", Some("5"))
        .parse_from(std::env::args().skip(1).collect())?;
    let mut rng = Pcg64::seed_from(args.get_u64("seed")?);

    // 1) corpus + tf-idf + SVD (the paper's preprocessing, simulated).
    let cfg = SurveyConfig {
        svd_dim: args.get_usize("svd-dim")?,
        ..Default::default()
    };
    let t = Timer::start();
    let data = survey::generate_ds1(args.get_f64("scale")?, &cfg, &mut rng);
    println!(
        "corpus: {} docs, {} raw tf-idf features -> {} dims (SVD) in {:.1}s",
        data.len(),
        data.raw_features,
        data.points.cols(),
        t.secs()
    );

    // 2) split train/test by document.
    let n = data.len();
    let perm = {
        use mlsvm::util::rng::Rng;
        rng.permutation(n)
    };
    let n_test = n / 5;
    let test_idx: Vec<usize> = perm[..n_test].to_vec();
    let train_idx: Vec<usize> = perm[n_test..].to_vec();
    let train_points = data.points.select_rows(&train_idx);
    let train_ids: Vec<u8> = train_idx.iter().map(|&i| data.class_ids[i]).collect();
    let test_points = data.points.select_rows(&test_idx);
    let test_ids: Vec<u8> = test_idx.iter().map(|&i| data.class_ids[i]).collect();

    // 3) one-vs-rest MLWSVM per class through the job queue.
    let mut trainer = OneVsRestTrainer::new(MlsvmParams::default().with_seed(77));
    trainer.verbose = true;
    let t = Timer::start();
    let model = trainer.train(&train_points, &train_ids, &[0, 1, 2, 3, 4], &mut rng)?;
    let total = t.secs();

    // 4) per-class report (Table-2 shape).
    let mut table = Table::new(&["Class", "train n+", "ACC", "κ", "Time(s)"]);
    for job in &model.jobs {
        let m = model.evaluate_class(job.class_id, &test_points, &test_ids);
        table.row(vec![
            format!("Class {}", job.class_id + 1),
            job.sizes.0.to_string(),
            format!("{:.2}", m.accuracy()),
            format!("{:.2}", m.gmean()),
            fmt_secs(job.seconds),
        ]);
    }
    println!("{}", table.render());

    // 5) multiclass argmax accuracy.
    let preds = model.predict_batch(&test_points);
    let correct = preds
        .iter()
        .zip(&test_ids)
        .filter(|(p, t)| p.map(|c| c == **t).unwrap_or(false))
        .count();
    println!(
        "multiclass argmax accuracy: {:.3} ({} classes, total {:.1}s)",
        correct as f64 / test_ids.len() as f64,
        model.jobs.len(),
        total
    );
    Ok(())
}
