//! Quickstart: train a multilevel WSVM on a small nonlinear problem and
//! serve predictions through the PJRT decision artifact.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use mlsvm::prelude::*;
use mlsvm::util::timer::Timer;

fn main() -> Result<()> {
    let mut rng = Pcg64::seed_from(7);

    // A minority ring around a majority core: linearly inseparable,
    // needs the RBF kernel the framework tunes automatically.
    let ds = mlsvm::data::synth::concentric_rings(4_000, 800, &mut rng);
    let (mut train, mut test) = mlsvm::data::split::train_test_split(&ds, 0.2, &mut rng);
    mlsvm::data::scale::Scaler::fit_transform(&mut train, Some(&mut test));
    println!(
        "data: n={} dim={} r_imb={:.2}",
        train.len(),
        train.dim(),
        train.imbalance()
    );

    // Train with paper defaults (k=10 k-NN, Q=0.5, η=2, caliber 2,
    // UD model selection with parameter inheritance).
    let t = Timer::start();
    let params = MlsvmParams::default().with_seed(7);
    let model = MlsvmTrainer::new(params).train(&train, &mut rng)?;
    println!("trained in {:.2}s through {} levels:", t.secs(), model.level_stats.len());
    for s in &model.level_stats {
        println!(
            "  level {:?}: train={} SVs={} UD={}",
            s.levels, s.train_size, s.n_sv, s.ud_used
        );
    }

    // Evaluate on held-out data.
    let m = mlsvm::metrics::evaluate(&model.model, &test);
    println!("test: {}", m.report());

    // Serve through the PJRT artifact router when artifacts are built.
    let dir = mlsvm::runtime::Runtime::default_dir();
    if dir.join("manifest.txt").exists() {
        let mut rt = mlsvm::runtime::Runtime::new(dir)?;
        let mut router = mlsvm::coordinator::Router::new_pjrt(
            &rt,
            &model.model,
            std::time::Duration::from_millis(2),
        )?;
        let t = Timer::start();
        let ids: Vec<u64> = (0..test.len())
            .map(|i| router.submit(test.points.row(i)))
            .collect();
        router.flush(&mut rt)?;
        let correct = ids
            .iter()
            .enumerate()
            .filter(|(i, id)| {
                let pred = if router.take(**id).unwrap() > 0.0 { 1 } else { -1 };
                pred == test.labels[*i]
            })
            .count();
        println!(
            "PJRT router: {} predictions in {:.3}s ({} batches, {:.0}% slot utilization), acc={:.3}",
            test.len(),
            t.secs(),
            router.stats().batches,
            100.0 * router.stats().utilization(),
            correct as f64 / test.len() as f64
        );
    } else {
        println!("(artifacts not built; run `make artifacts` for the PJRT demo)");
    }
    Ok(())
}
