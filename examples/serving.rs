//! Serving walkthrough: train → save to the registry → serve over HTTP →
//! query → hot-reload — the full path from the paper's training framework
//! to an online decision service.
//!
//! ```bash
//! cargo run --release --example serving
//! ```

use mlsvm::prelude::*;
use mlsvm::serve::{http_request, ServeState, Server};
use mlsvm::util::timer::Timer;
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn main() -> Result<()> {
    let mut rng = Pcg64::seed_from(21);

    // 1. Train a small multilevel WSVM.
    let ds = mlsvm::data::synth::two_gaussians(1_500, 350, 8, 3.5, &mut rng);
    let (mut train, mut test) = mlsvm::data::split::train_test_split(&ds, 0.25, &mut rng);
    mlsvm::data::scale::Scaler::fit_transform(&mut train, Some(&mut test));
    let t = Timer::start();
    let params = MlsvmParams {
        hierarchy: mlsvm::amg::hierarchy::HierarchyParams {
            coarsest_size: 100,
            ..Default::default()
        },
        qdt: 500,
        ..Default::default()
    }
    .with_seed(21);
    let model = MlsvmTrainer::new(params).train(&train, &mut rng)?;
    let m = mlsvm::metrics::evaluate(&model.model, &test);
    println!(
        "trained in {:.2}s through {} levels | test {}",
        t.secs(),
        model.level_stats.len(),
        m.report()
    );

    // 2. Publish the FULL multilevel model (params + level metadata, not
    //    just the finest line file) into a named registry.
    let dir = std::env::temp_dir().join("mlsvm_example_registry");
    let reg = Registry::open(&dir)?;
    let artifact = ModelArtifact::Mlsvm(model);
    reg.save("rings-v1", &artifact)?;
    println!(
        "registry {}: {:?}",
        dir.display(),
        reg.list()?
    );

    // 3. Load it back and start the serving stack: batching engine +
    //    HTTP front end on an ephemeral port.
    let served = reg.load("rings-v1")?;
    println!("serving: {}", served.describe());
    let engine = Engine::new(
        &served,
        EngineConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
    )?;
    let state = Arc::new(ServeState {
        engine,
        registry: Some(Registry::open(&dir)?),
        model_name: Mutex::new("rings-v1".into()),
    });
    let mut server = Server::start("127.0.0.1:0", Arc::clone(&state))?;
    let addr = server.addr();
    println!("listening on http://{addr}");

    // 4. Query it like any HTTP client would.
    let body: Vec<String> = test.points.row(0).iter().map(|v| v.to_string()).collect();
    let (code, resp) = http_request(&addr, "POST", "/predict", &body.join(","))?;
    println!("POST /predict -> {code}: {resp}");

    let mut batch = String::new();
    for i in 0..5 {
        let row: Vec<String> = test.points.row(i).iter().map(|v| v.to_string()).collect();
        batch.push_str(&row.join(","));
        batch.push('\n');
    }
    let (code, resp) = http_request(&addr, "POST", "/predict-batch", &batch)?;
    println!("POST /predict-batch (5 rows) -> {code}: {} bytes", resp.len());

    let (_, resp) = http_request(&addr, "GET", "/models", "")?;
    println!("GET /models -> {resp}");

    // 5. Hot-reload: publish a second version and swap it in while the
    //    server keeps answering.
    reg.save("rings-v2", &served)?;
    let (code, resp) = http_request(&addr, "POST", "/reload?model=rings-v2", "")?;
    println!("POST /reload -> {code}: {resp}");

    let (_, resp) = http_request(&addr, "GET", "/stats", "")?;
    println!("GET /stats -> {resp}");

    server.shutdown();
    println!("done");
    Ok(())
}
