//! Serving walkthrough: train → publish to the registry (v2 binary) →
//! serve **two models** behind one routed HTTP server → query both →
//! hot-reload — the full path from the paper's training framework to a
//! multi-tenant online decision service.
//!
//! ```bash
//! cargo run --release --example serving
//! ```

use mlsvm::prelude::*;
use mlsvm::serve::{http_request, EngineManager, ServeState, Server};
use mlsvm::util::timer::Timer;
use std::sync::Arc;
use std::time::Duration;

fn main() -> Result<()> {
    let mut rng = Pcg64::seed_from(21);

    // 1. Train a small multilevel WSVM.
    let ds = mlsvm::data::synth::two_gaussians(1_500, 350, 8, 3.5, &mut rng);
    let (mut train, mut test) = mlsvm::data::split::train_test_split(&ds, 0.25, &mut rng);
    mlsvm::data::scale::Scaler::fit_transform(&mut train, Some(&mut test));
    let t = Timer::start();
    let params = MlsvmParams {
        hierarchy: mlsvm::amg::hierarchy::HierarchyParams {
            coarsest_size: 100,
            ..Default::default()
        },
        qdt: 500,
        ..Default::default()
    }
    .with_seed(21);
    let model = MlsvmTrainer::new(params).train(&train, &mut rng)?;
    let m = mlsvm::metrics::evaluate(&model.model, &test);
    println!(
        "trained in {:.2}s through {} levels | test {}",
        t.secs(),
        model.level_stats.len(),
        m.report()
    );

    // 2. Publish the FULL multilevel model (params + level metadata) into
    //    a named registry — written in the v2 binary format — plus a
    //    plain finest-level SVM as a second serveable model.
    let dir = std::env::temp_dir().join("mlsvm_example_registry");
    let reg = Registry::open(&dir)?;
    reg.save("rings-v1", &ModelArtifact::Mlsvm(model.clone()))?;
    reg.save("rings-flat", &ModelArtifact::Svm(model.model.clone()))?;
    println!("registry {}: {:?}", dir.display(), reg.list()?);
    println!(
        "on disk: {} ({})",
        reg.path_of("rings-v1").display(),
        mlsvm::serve::detect_format(reg.path_of("rings-v1"))?
    );

    // 3. Start the serving stack: an engine manager that lazily spawns
    //    one batching engine per model, behind the routed HTTP front end
    //    on an ephemeral port. "rings-v1" is the default model (legacy
    //    unprefixed routes resolve to it).
    let manager = EngineManager::open(
        Registry::open(&dir)?,
        EngineConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
    );
    let state = Arc::new(ServeState::new(manager, "rings-v1"));
    let mut server = Server::start("127.0.0.1:0", Arc::clone(&state))?;
    let addr = server.addr();
    println!("listening on http://{addr}");

    // 4. Query both models by name — one server, one engine per model.
    let body: Vec<String> = test.points.row(0).iter().map(|v| v.to_string()).collect();
    let body = body.join(",");
    let (code, resp) = http_request(&addr, "POST", "/v1/models/rings-v1/predict", &body)?;
    println!("POST /v1/models/rings-v1/predict -> {code}: {resp}");
    let (code, resp) = http_request(&addr, "POST", "/v1/models/rings-flat/predict", &body)?;
    println!("POST /v1/models/rings-flat/predict -> {code}: {resp}");

    // Legacy unprefixed routes keep working, mapped to the default.
    let (code, resp) = http_request(&addr, "POST", "/predict", &body)?;
    println!("POST /predict (legacy -> default) -> {code}: {resp}");

    let mut batch = String::new();
    for i in 0..5 {
        let row: Vec<String> = test.points.row(i).iter().map(|v| v.to_string()).collect();
        batch.push_str(&row.join(","));
        batch.push('\n');
    }
    let (code, resp) =
        http_request(&addr, "POST", "/v1/models/rings-v1/predict-batch", &batch)?;
    println!(
        "POST /v1/models/rings-v1/predict-batch (5 rows) -> {code}: {} bytes",
        resp.len()
    );

    // 5. Per-model stats and the fleet listing.
    let (_, resp) = http_request(&addr, "GET", "/v1/models/rings-flat/stats", "")?;
    println!("GET /v1/models/rings-flat/stats -> {resp}");
    let (_, resp) = http_request(&addr, "GET", "/v1/models", "")?;
    println!("GET /v1/models -> {resp}");

    // 6. Hot-reload: publish a new version under a name and swap it in
    //    while the server keeps answering (routed reload; the default
    //    model is untouched).
    reg.save("rings-flat", &ModelArtifact::Svm(model.model.clone()))?;
    let (code, resp) = http_request(&addr, "POST", "/v1/models/rings-flat/reload", "")?;
    println!("POST /v1/models/rings-flat/reload -> {code}: {resp}");

    server.shutdown();
    println!("done");
    Ok(())
}
