//! WSVM vs MLWSVM on an imbalanced Table-1-style workload — the paper's
//! headline comparison (quality preserved, large speedup), on a single
//! data set so it runs in seconds.
//!
//! ```bash
//! cargo run --release --example imbalanced_wsvm -- [--name Hypothyroid] [--scale 1.0]
//! ```

use mlsvm::coordinator::report::{fmt_secs, Table};
use mlsvm::data::synth::uci;
use mlsvm::error::Error;
use mlsvm::modelsel::search::ud_search;
use mlsvm::prelude::*;
use mlsvm::svm::smo::train_weighted;
use mlsvm::util::cli::Args;
use mlsvm::util::timer::Timer;

fn main() -> Result<()> {
    let args = Args::new("imbalanced_wsvm", "WSVM vs MLWSVM on one data set")
        .opt("name", "Table-1 data set name", Some("Hypothyroid"))
        .opt("scale", "size scale (1.0 = paper size)", Some("1.0"))
        .opt("seed", "random seed", Some("1"))
        .parse_from(std::env::args().skip(1).collect())?;
    let name = args.get("name").unwrap();
    let spec = uci::spec_by_name(name)
        .ok_or_else(|| Error::Usage(format!("unknown data set '{name}'")))?;
    let mut rng = Pcg64::seed_from(args.get_u64("seed")?);
    let ds = spec.generate(args.get_f64("scale")?, &mut rng);
    let (mut train, mut test) = mlsvm::data::split::train_test_split(&ds, 0.2, &mut rng);
    mlsvm::data::scale::Scaler::fit_transform(&mut train, Some(&mut test));
    println!(
        "{}: n={} n_f={} |C+|={} |C-|={} r_imb={:.2}",
        spec.name,
        train.len(),
        train.dim(),
        train.n_pos(),
        train.n_neg(),
        train.imbalance()
    );

    // --- baseline: full WSVM with UD model selection on ALL points ---
    let t = Timer::start();
    let ud = mlsvm::modelsel::search::UdSearchConfig::default();
    let outcome = ud_search(&train, false, &ud, None, &mut rng)?;
    let base_model = train_weighted(&train.points, &train.labels, &outcome.params, None)?;
    let base_secs = t.secs();
    let base_m = mlsvm::metrics::evaluate(&base_model, &test);

    // --- MLWSVM ---
    let t = Timer::start();
    let ml = MlsvmTrainer::new(MlsvmParams::default().with_seed(11)).train(&train, &mut rng)?;
    let ml_secs = t.secs();
    let ml_m = mlsvm::metrics::evaluate(&ml.model, &test);

    let mut table = Table::new(&["Method", "ACC", "SN", "SP", "κ", "Time(s)"]);
    for (nm, m, s) in [("WSVM", base_m, base_secs), ("MLWSVM", ml_m, ml_secs)] {
        table.row(vec![
            nm.into(),
            format!("{:.2}", m.accuracy()),
            format!("{:.2}", m.sensitivity()),
            format!("{:.2}", m.specificity()),
            format!("{:.2}", m.gmean()),
            fmt_secs(s),
        ]);
    }
    println!("{}", table.render());
    println!(
        "speedup: {:.1}x (κ delta {:+.3})",
        base_secs / ml_secs.max(1e-9),
        ml_m.gmean() - base_m.gmean()
    );
    Ok(())
}
