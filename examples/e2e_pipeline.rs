//! End-to-end validation driver (DESIGN.md §7): exercises every layer of
//! the system on a realistic imbalanced workload and reports the paper's
//! headline metric — MLWSVM reaches full-WSVM quality at a fraction of
//! the time — with the PJRT artifact on the serving path.
//!
//! Pipeline: generate Forest-analog data (paper-statistics, scaled) →
//! z-score → per-class AMG hierarchies over approximate k-NN graphs →
//! coarsest UD learning → SV-guided uncoarsening → final model → batched
//! prediction through the PJRT decision artifact router → metrics, vs the
//! full WSVM baseline trained on all points.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline -- [--scale 0.034]
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use mlsvm::coordinator::report::{fmt_secs, Table};
use mlsvm::data::synth::uci;
use mlsvm::modelsel::search::ud_search;
use mlsvm::prelude::*;
use mlsvm::svm::smo::train_weighted;
use mlsvm::util::cli::Args;
use mlsvm::util::timer::Timer;

fn main() -> Result<()> {
    let args = Args::new("e2e_pipeline", "end-to-end MLWSVM vs WSVM + PJRT serving")
        .opt("name", "Table-1 data set", Some("Forest"))
        .opt("scale", "size scale vs paper n", Some("0.034"))
        .opt("seed", "random seed", Some("20"))
        .flag("skip-baseline", "only run the multilevel side")
        .parse_from(std::env::args().skip(1).collect())?;
    let spec = uci::spec_by_name(args.get("name").unwrap()).expect("known data set");
    let mut rng = Pcg64::seed_from(args.get_u64("seed")?);
    let scale = args.get_f64("scale")?;
    let ds = spec.generate(scale, &mut rng);
    let (mut train, mut test) = mlsvm::data::split::train_test_split(&ds, 0.2, &mut rng);
    mlsvm::data::scale::Scaler::fit_transform(&mut train, Some(&mut test));
    println!(
        "workload: {} @ scale {scale} -> n={} (paper n={}) n_f={} r_imb={:.3}",
        spec.name,
        train.len() + test.len(),
        spec.n(),
        train.dim(),
        ds.imbalance()
    );

    // ---- multilevel training ----
    let t = Timer::start();
    let model = MlsvmTrainer::new(MlsvmParams::default().with_seed(21)).train(&train, &mut rng)?;
    let ml_secs = t.secs();
    println!("\nMLWSVM hierarchy ({} levels):", model.level_stats.len());
    for s in &model.level_stats {
        println!(
            "  ({:>2},{:>2})  train={:<6} SVs={:<5} UD={:<5} {}s",
            s.levels.0,
            s.levels.1,
            s.train_size,
            s.n_sv,
            s.ud_used,
            fmt_secs(s.seconds)
        );
    }

    // ---- serving through the PJRT artifact ----
    let dir = mlsvm::runtime::Runtime::default_dir();
    let ml_m = if dir.join("manifest.txt").exists() {
        let mut rt = mlsvm::runtime::Runtime::new(dir)?;
        let mut router = mlsvm::coordinator::Router::new_pjrt(
            &rt,
            &model.model,
            std::time::Duration::from_millis(2),
        )?;
        let t = Timer::start();
        let ids: Vec<u64> = (0..test.len())
            .map(|i| router.submit(test.points.row(i)))
            .collect();
        router.flush(&mut rt)?;
        let preds: Vec<i8> = ids
            .iter()
            .map(|id| if router.take(*id).unwrap() > 0.0 { 1 } else { -1 })
            .collect();
        let serve_secs = t.secs();
        println!(
            "\nPJRT serving: {} queries in {:.3}s = {:.0} q/s ({} batches, {:.0}% utilization)",
            test.len(),
            serve_secs,
            test.len() as f64 / serve_secs.max(1e-9),
            router.stats().batches,
            100.0 * router.stats().utilization()
        );
        mlsvm::metrics::Metrics::from_labels(&test.labels, &preds)
    } else {
        println!("\n(artifacts missing; evaluating on the rust path)");
        mlsvm::metrics::evaluate(&model.model, &test)
    };

    // ---- baseline: full WSVM + UD on all points ----
    let mut table = Table::new(&["Method", "ACC", "SN", "SP", "κ", "Train(s)"]);
    table.row(vec![
        "MLWSVM".into(),
        format!("{:.2}", ml_m.accuracy()),
        format!("{:.2}", ml_m.sensitivity()),
        format!("{:.2}", ml_m.specificity()),
        format!("{:.2}", ml_m.gmean()),
        fmt_secs(ml_secs),
    ]);
    if !args.get_flag("skip-baseline") {
        let t = Timer::start();
        let ud = mlsvm::modelsel::search::UdSearchConfig::default();
        let outcome = ud_search(&train, false, &ud, None, &mut rng)?;
        let base = train_weighted(&train.points, &train.labels, &outcome.params, None)?;
        let base_secs = t.secs();
        let base_m = mlsvm::metrics::evaluate(&base, &test);
        table.row(vec![
            "WSVM".into(),
            format!("{:.2}", base_m.accuracy()),
            format!("{:.2}", base_m.sensitivity()),
            format!("{:.2}", base_m.specificity()),
            format!("{:.2}", base_m.gmean()),
            fmt_secs(base_secs),
        ]);
        println!("\n{}", table.render());
        println!(
            "headline: {:.1}x speedup, κ {:+.3}",
            base_secs / ml_secs.max(1e-9),
            ml_m.gmean() - base_m.gmean()
        );
    } else {
        println!("\n{}", table.render());
    }
    Ok(())
}
