//! "Does AMG help?" — sweep the interpolation order R on one data set
//! (a single-row slice of Table 3). Higher R lets fine points split
//! across more aggregates, tracking the data manifold more accurately at
//! the price of denser coarse graphs and more time.
//!
//! ```bash
//! cargo run --release --example interpolation_order -- [--name Hypothyroid]
//! ```

use mlsvm::coordinator::report::{fmt_secs, Table};
use mlsvm::data::synth::uci;
use mlsvm::error::Error;
use mlsvm::prelude::*;
use mlsvm::util::cli::Args;
use mlsvm::util::timer::Timer;

fn main() -> Result<()> {
    let args = Args::new("interpolation_order", "κ and time vs caliber R")
        .opt("name", "Table-1 data set name", Some("Hypothyroid"))
        .opt("scale", "size scale", Some("1.0"))
        .opt("seed", "random seed", Some("3"))
        .parse_from(std::env::args().skip(1).collect())?;
    let spec = uci::spec_by_name(args.get("name").unwrap())
        .ok_or_else(|| Error::Usage("unknown data set".into()))?;
    let mut rng = Pcg64::seed_from(args.get_u64("seed")?);
    let ds = spec.generate(args.get_f64("scale")?, &mut rng);
    let (mut train, mut test) = mlsvm::data::split::train_test_split(&ds, 0.2, &mut rng);
    mlsvm::data::scale::Scaler::fit_transform(&mut train, Some(&mut test));
    println!("{}: n={} n_f={}", spec.name, train.len(), train.dim());

    let mut table = Table::new(&["R", "κ", "ACC", "SN", "SP", "Time(s)", "levels"]);
    for r in [1usize, 2, 4, 6, 8, 10] {
        let t = Timer::start();
        let params = MlsvmParams::default().with_caliber(r).with_seed(100 + r as u64);
        let model = MlsvmTrainer::new(params).train(&train, &mut rng)?;
        let secs = t.secs();
        let m = mlsvm::metrics::evaluate(&model.model, &test);
        table.row(vec![
            r.to_string(),
            format!("{:.2}", m.gmean()),
            format!("{:.2}", m.accuracy()),
            format!("{:.2}", m.sensitivity()),
            format!("{:.2}", m.specificity()),
            fmt_secs(secs),
            model.level_stats.len().to_string(),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}
